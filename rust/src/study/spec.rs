//! Declarative sweep studies: a base [`Scenario`] plus named axes.
//!
//! Every paper result is a *grid* over scenario knobs — protection
//! fraction, ADC resolution, sigma, wordline group, method, model, seed.
//! A [`Study`] names that grid once: the base scenario carries everything
//! the axes do not touch, each [`Axis`] lists the values of one knob, and
//! the cross product (first axis outermost) is the experiment. Like
//! [`Scenario`], a study round-trips through [`crate::util::json`]:
//!
//! ```json
//! {
//!   "name": "frac-method",
//!   "base": { "model": "synthetic", "split": {"kind": "channels", "frac": 0.16},
//!             "backend": "native", "n_eval": 128, "repeats": 2, "seed": 1234 },
//!   "axes": [
//!     {"key": "method", "values": ["hybrid", "iws"]},
//!     {"key": "frac",   "values": [0, 0.08, 0.16, 0.24]}
//!   ]
//! }
//! ```
//!
//! Axis kinds: `frac`, `method`, `adc_bits`, `sigma`, `group`, `model`,
//! `seed`, `variant` (named multi-field patches for non-cross-product
//! designs like Table 2's differential column), and `search` — the
//! Algorithm-1 `find_protection` crossing wrapped as an axis, so Table 1's
//! "%weights each method must protect" is one grid too. Parsing is strict
//! throughout (mirroring `Scenario.backend`): an unknown axis key, a
//! misspelled field, or a mistyped value fails the parse instead of
//! silently running a different experiment than the file says.
//!
//! [`Study::named`] holds the built-in studies behind the paper benches
//! and the `sweep`/`adc`/`select` CLI subcommands; `hybridac study --list`
//! prints them.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::eval::Method;
use crate::noise::{fig11_scenario, CellKind, CellModel};
use crate::quantize::QuantConfig;
use crate::scenario::Scenario;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// bench budget + model combos (moved here from the old `benchkit` — the study layer
// owns the sweep configuration now)

/// `HYBRIDAC_BENCH_FULL=1` restores the paper-scale sweep budget.
pub fn full_mode() -> bool {
    std::env::var("HYBRIDAC_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// (n_eval, repeats) for accuracy studies: reduced-but-faithful by
/// default, paper-scale under [`full_mode`].
pub fn eval_budget() -> (usize, usize) {
    if full_mode() {
        (1000, 5)
    } else {
        (250, 2)
    }
}

/// All (tag, pretty) model combos per dataset, in the paper's table order.
pub fn model_combos(dataset: &str) -> Vec<(String, &'static str)> {
    let fams: &[(&str, &str)] = match dataset {
        "in50s" => &[
            ("resnet18m", "ResNet18"),
            ("resnet34m", "ResNet34"),
            ("densenetm", "DenseNet121"),
        ],
        _ => &[
            ("vggmini", "VGG16"),
            ("resnet18m", "ResNet18"),
            ("resnet34m", "ResNet34"),
            ("densenetm", "DenseNet121"),
            ("effnetm", "EfficientNetB3"),
        ],
    };
    fams.iter()
        .map(|(f, p)| (format!("{f}_{dataset}"), *p))
        .collect()
}

/// Whether `tag`'s artifact has been exported into `dir`.
pub fn artifact_built(dir: &Path, tag: &str) -> bool {
    dir.join(format!("{tag}.meta.json")).exists()
}

/// [`model_combos`] filtered to built artifacts (the same filter the
/// runner applies to `model` axes); prints a notice per missing artifact
/// so truncation is never silent.
pub fn built_model_combos(dir: &Path, dataset: &str) -> Vec<(String, &'static str)> {
    model_combos(dataset)
        .into_iter()
        .filter(|(tag, _)| {
            let ok = artifact_built(dir, tag);
            if !ok {
                eprintln!("[study] skipping {tag}: artifact not built");
            }
            ok
        })
        .collect()
}

// ---------------------------------------------------------------------------
// axis value types

/// Protection method named by a `method` axis or a variant patch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKey {
    /// HybridAC channel-wise selection (keeps the current fraction).
    Hybrid,
    /// IWS individual-weight selection (keeps the current fraction).
    Iws,
    /// Everything analog under the base perturbations.
    Unprotected,
    /// Everything analog, no quant/perturb/ADC (pipeline anchor).
    Clean,
}

impl MethodKey {
    pub fn parse(s: &str) -> Result<MethodKey> {
        Ok(match s {
            "hybrid" => MethodKey::Hybrid,
            "iws" => MethodKey::Iws,
            "unprotected" => MethodKey::Unprotected,
            "clean" => MethodKey::Clean,
            other => bail!("unknown method '{other}' (hybrid|iws|unprotected|clean)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            MethodKey::Hybrid => "hybrid",
            MethodKey::Iws => "iws",
            MethodKey::Unprotected => "unprotected",
            MethodKey::Clean => "clean",
        }
    }
}

/// One value of a `search` axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchValue {
    /// No search: evaluate the base point as-is (Table 1's "with PV"
    /// column rides along the method crossings this way).
    None,
    /// Find HybridAC's protected-fraction crossing.
    Hybrid,
    /// Find IWS's protected-fraction crossing.
    Iws,
}

impl SearchValue {
    pub fn parse(s: &str) -> Result<SearchValue> {
        Ok(match s {
            "none" => SearchValue::None,
            "hybrid" => SearchValue::Hybrid,
            "iws" => SearchValue::Iws,
            other => bail!("unknown search value '{other}' (none|hybrid|iws)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SearchValue::None => "none",
            SearchValue::Hybrid => "hybrid",
            SearchValue::Iws => "iws",
        }
    }
}

/// Parameters of the Algorithm-1 crossing wrapped by a `search` axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchParams {
    /// Accuracy target = measured clean accuracy − `target_drop`.
    pub target_drop: f64,
    /// Give up (and report the boundary point) past this fraction.
    pub max_frac: f64,
    /// Fraction increment per step (the paper pops single channels; the
    /// benches pop 1-2%-of-weights chunks).
    pub step: f64,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { target_drop: 0.02, max_frac: 0.30, step: 0.02 }
    }
}

/// One named value of a `variant` axis: a multi-field patch on the base
/// scenario, for designs that are not a cross product of single knobs
/// (Table 2's 4-bit differential corner, Fig. 8's design-point ladder).
/// Absent fields keep the base value; `quant`/`adc_bits` distinguish
/// "absent" (keep) from JSON `null` (set to none/ideal).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct VariantPatch {
    pub name: String,
    pub method: Option<MethodKey>,
    pub frac: Option<f64>,
    pub cell: Option<CellModel>,
    pub sigma: Option<f64>,
    pub quant: Option<Option<QuantConfig>>,
    pub adc_bits: Option<Option<u32>>,
    pub group: Option<usize>,
    pub seed: Option<u64>,
}

/// One sweep axis: the knob it turns and the values it takes.
#[derive(Clone, Debug, PartialEq)]
pub enum Axis {
    /// Protected-weight fraction of the current channels/iws split.
    Frac(Vec<f64>),
    /// Protection method (keeps the current fraction for hybrid/iws).
    Method(Vec<MethodKey>),
    /// ADC resolution; `None` (JSON `null`) = ideal readout.
    AdcBits(Vec<Option<u32>>),
    /// Analog-variation sigma (inserts the variation stage if absent).
    Sigma(Vec<f64>),
    /// Simultaneously activated wordlines.
    Group(Vec<usize>),
    /// Model artifact tag.
    Model(Vec<String>),
    /// Master seed of the repeat RNG.
    Seed(Vec<u64>),
    /// Named multi-field patches (see [`VariantPatch`]).
    Variant(Vec<VariantPatch>),
    /// Algorithm-1 crossing per value (see [`SearchValue`]); cannot be
    /// combined with `method`/`frac` axes — the search owns the split.
    Search { values: Vec<SearchValue>, params: SearchParams },
}

impl Axis {
    /// The JSON `key` naming this axis kind.
    pub fn key(&self) -> &'static str {
        match self {
            Axis::Frac(_) => "frac",
            Axis::Method(_) => "method",
            Axis::AdcBits(_) => "adc_bits",
            Axis::Sigma(_) => "sigma",
            Axis::Group(_) => "group",
            Axis::Model(_) => "model",
            Axis::Seed(_) => "seed",
            Axis::Variant(_) => "variant",
            Axis::Search { .. } => "search",
        }
    }

    /// Number of values (grid width along this axis).
    pub fn len(&self) -> usize {
        match self {
            Axis::Frac(v) => v.len(),
            Axis::Method(v) => v.len(),
            Axis::AdcBits(v) => v.len(),
            Axis::Sigma(v) => v.len(),
            Axis::Group(v) => v.len(),
            Axis::Model(v) => v.len(),
            Axis::Seed(v) => v.len(),
            Axis::Variant(v) => v.len(),
            Axis::Search { values, .. } => values.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// the study itself

/// A declarative sweep: base scenario + axes (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct Study {
    pub name: String,
    pub base: Scenario,
    pub axes: Vec<Axis>,
}

impl Study {
    /// Structural sanity of the axes; called by the parser and by the
    /// grid expander, so a hand-built study fails just as loudly as a
    /// mistyped file.
    pub fn validate(&self) -> Result<()> {
        let mut seen: Vec<&'static str> = Vec::new();
        for axis in &self.axes {
            let key = axis.key();
            if seen.contains(&key) {
                bail!("study '{}': duplicate '{key}' axis", self.name);
            }
            seen.push(key);
            if axis.is_empty() {
                bail!("study '{}': axis '{key}' has no values", self.name);
            }
            match axis {
                Axis::Search { params, .. } => {
                    if params.step <= 0.0 {
                        bail!("study '{}': search step must be positive", self.name);
                    }
                    if !(params.target_drop.is_finite() && params.max_frac.is_finite()) {
                        bail!("study '{}': search parameters must be finite", self.name);
                    }
                }
                Axis::Variant(patches) => {
                    let mut names: Vec<&str> = Vec::new();
                    for p in patches {
                        if p.name.is_empty() {
                            bail!("study '{}': variant without a name", self.name);
                        }
                        if p.name.chars().any(|c| matches!(c, ',' | '=' | '/')) {
                            bail!(
                                "study '{}': variant name '{}' may not contain ',', '=' or '/' \
                                 (they delimit point IDs)",
                                self.name,
                                p.name
                            );
                        }
                        if names.contains(&p.name.as_str()) {
                            bail!("study '{}': duplicate variant '{}'", self.name, p.name);
                        }
                        names.push(&p.name);
                    }
                }
                _ => {}
            }
        }
        if seen.contains(&"search") && (seen.contains(&"method") || seen.contains(&"frac")) {
            bail!(
                "study '{}': a 'search' axis cannot be combined with 'method' or 'frac' axes \
                 (the search owns the split)",
                self.name
            );
        }
        let total: usize = self.axes.iter().map(Axis::len).product();
        if total > 100_000 {
            bail!("study '{}': {total} grid points is past the 100k sanity cap", self.name);
        }
        Ok(())
    }

    // -- JSON ---------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("base".to_string(), self.base.to_json());
        m.insert(
            "axes".to_string(),
            Json::Arr(self.axes.iter().map(axis_to_json).collect()),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Study> {
        check_keys(j, &["name", "base", "axes"], "study")?;
        let name = match j.get("name") {
            None | Some(Json::Null) => "study".to_string(),
            Some(v) => v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("study 'name' is not a string"))?
                .to_string(),
        };
        let base = Scenario::from_json(j.req("base")?).context("study 'base'")?;
        let mut axes = Vec::new();
        if let Some(arr) = j.get("axes") {
            for (i, a) in arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("study 'axes' is not an array"))?
                .iter()
                .enumerate()
            {
                axes.push(axis_from_json(a).with_context(|| format!("study 'axes'[{i}]"))?);
            }
        }
        let study = Study { name, base, axes };
        study.validate()?;
        Ok(study)
    }

    pub fn parse(text: &str) -> Result<Study> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Study::from_json(&j)
    }

    pub fn load(path: &Path) -> Result<Study> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading study spec {}", path.display()))?;
        Study::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- built-ins ----------------------------------------------------------

    /// Named built-in studies: the paper benches and the `sweep`/`adc`/
    /// `select` CLI subcommands, re-expressed declaratively. `model` seeds
    /// the base scenario of single-model studies; dataset-wide studies
    /// (`table*-<dataset>`, `fig7`) carry their own `model` axis and
    /// ignore it.
    pub fn named(key: &str, model: &str) -> Option<Study> {
        let (n_eval, repeats) = eval_budget();
        let model = if model.is_empty() { "resnet18m_c10s" } else { model };
        let base =
            |m: Method| Scenario::paper_default(key, model, m).with_eval(n_eval, repeats);
        Some(match key {
            "sweep" => Study {
                name: key.to_string(),
                base: base(Method::NoProtection),
                axes: vec![
                    Axis::Method(vec![MethodKey::Hybrid, MethodKey::Iws]),
                    Axis::Frac(vec![0.0, 0.02, 0.04, 0.08, 0.12, 0.16, 0.20]),
                ],
            },
            "adc" => Study {
                name: key.to_string(),
                base: base(Method::Hybrid { frac: 0.16 }),
                axes: vec![
                    Axis::Method(vec![MethodKey::Hybrid, MethodKey::Iws]),
                    Axis::AdcBits(vec![Some(8), Some(7), Some(6), Some(4)]),
                ],
            },
            "select" => Study {
                name: key.to_string(),
                base: base(Method::NoProtection),
                axes: vec![Axis::Search {
                    values: vec![SearchValue::Hybrid],
                    params: SearchParams { target_drop: 0.01, max_frac: 0.40, step: 0.01 },
                }],
            },
            "fig7" => Study {
                name: key.to_string(),
                base: Scenario::paper_default(key, "", Method::NoProtection)
                    .with_eval(n_eval, repeats),
                axes: vec![
                    model_axis("in50s")?,
                    Axis::Method(vec![MethodKey::Hybrid, MethodKey::Iws]),
                    Axis::Frac(vec![0.0, 0.04, 0.08, 0.12, 0.16, 0.20, 0.25]),
                ],
            },
            "fig8" => fig8(key, &base(Method::Hybrid { frac: 0.16 })),
            "fig11" => fig11(key, &base(Method::NoProtection)),
            _ => {
                if let Some(ds) = key.strip_prefix("table1-") {
                    table1(key, ds, n_eval, repeats)?
                } else if let Some(ds) = key.strip_prefix("table2-") {
                    table2(key, ds, n_eval, repeats)?
                } else if let Some(ds) = key.strip_prefix("table3-") {
                    table3(key, ds, n_eval, repeats)?
                } else {
                    return None;
                }
            }
        })
    }

    /// `(key, description)` of every built-in study (`study --list`).
    pub fn builtin_names() -> &'static [(&'static str, &'static str)] {
        &[
            ("sweep", "method x protected-fraction recovery grid on --model"),
            ("adc", "method x ADC-resolution grid at 16% protected on --model"),
            ("select", "Algorithm-1 crossing search (HybridAC) on --model"),
            ("table1-c10s", "clean/PV + per-method crossings, CIFAR10-analog models"),
            ("table1-c100s", "clean/PV + per-method crossings, CIFAR100-analog models"),
            ("table2-c10s", "ADC-resolution designs incl. 4b differential, c10s"),
            ("table2-c100s", "ADC-resolution designs incl. 4b differential, c100s"),
            ("table2-in50s", "ADC-resolution designs incl. 4b differential, in50s"),
            ("table3-c10s", "hybrid-quantization designs, c10s"),
            ("table3-c100s", "hybrid-quantization designs, c100s"),
            ("table3-in50s", "hybrid-quantization designs, in50s"),
            ("fig7", "accuracy vs %protected, ImageNet-analog models"),
            ("fig8", "design-point ladder (ADC/quant/differential variants)"),
            ("fig11", "accuracy vs activated wordlines across device corners"),
        ]
    }
}

/// A `model` axis over the dataset's paper combos; `None` for a dataset
/// the paper does not study.
fn model_axis(dataset: &str) -> Option<Axis> {
    if !["c10s", "c100s", "in50s"].contains(&dataset) {
        return None;
    }
    Some(Axis::Model(model_combos(dataset).into_iter().map(|(tag, _)| tag).collect()))
}

fn table1(key: &str, ds: &str, n_eval: usize, repeats: usize) -> Option<Study> {
    if ds == "in50s" {
        return None; // Table 1 is the CIFAR-analog table
    }
    let step = if full_mode() { 0.01 } else { 0.02 };
    Some(Study {
        name: key.to_string(),
        base: Scenario::paper_default(key, "", Method::NoProtection).with_eval(n_eval, repeats),
        axes: vec![
            model_axis(ds)?,
            Axis::Search {
                values: vec![SearchValue::None, SearchValue::Iws, SearchValue::Hybrid],
                params: SearchParams { target_drop: 0.02, max_frac: 0.30, step },
            },
        ],
    })
}

fn table2(key: &str, ds: &str, n_eval: usize, repeats: usize) -> Option<Study> {
    let off = CellModel::offset(0.5);
    let di = CellModel::differential(0.5);
    let v = |name: &str, m: MethodKey, bits: u32, cell: CellModel| VariantPatch {
        name: name.to_string(),
        method: Some(m),
        adc_bits: Some(Some(bits)),
        cell: Some(cell),
        ..VariantPatch::default()
    };
    Some(Study {
        name: key.to_string(),
        base: Scenario::paper_default(key, "", Method::Hybrid { frac: 0.16 })
            .with_eval(n_eval, repeats),
        axes: vec![
            model_axis(ds)?,
            Axis::Variant(vec![
                v("8b-HybAC", MethodKey::Hybrid, 8, off),
                v("8b-IWS", MethodKey::Iws, 8, off),
                v("7b-HybAC", MethodKey::Hybrid, 7, off),
                v("7b-IWS", MethodKey::Iws, 7, off),
                v("6b-HybAC", MethodKey::Hybrid, 6, off),
                v("6b-IWS", MethodKey::Iws, 6, off),
                v("4b-HACDi", MethodKey::Hybrid, 4, di),
                v("4b-IWSDi", MethodKey::Iws, 4, di),
            ]),
        ],
    })
}

fn table3(key: &str, ds: &str, n_eval: usize, repeats: usize) -> Option<Study> {
    let v = |name: &str, quant: QuantConfig, bits: u32| VariantPatch {
        name: name.to_string(),
        quant: Some(Some(quant)),
        adc_bits: Some(Some(bits)),
        ..VariantPatch::default()
    };
    Some(Study {
        name: key.to_string(),
        base: Scenario::paper_default(key, "", Method::Hybrid { frac: 0.16 })
            .with_eval(n_eval, repeats),
        axes: vec![
            model_axis(ds)?,
            Axis::Variant(vec![
                v("u8-adc8", QuantConfig::uniform8(), 8),
                v("h86-adc8", QuantConfig::hybrid(), 8),
                v("h86-adc6", QuantConfig::hybrid(), 6),
            ]),
        ],
    })
}

/// Fig. 8's design-point ladder; the bench maps variant names to the
/// matching architecture efficiencies.
fn fig8(key: &str, base: &Scenario) -> Study {
    let adc = |name: &str, bits: u32| VariantPatch {
        name: name.to_string(),
        adc_bits: Some(Some(bits)),
        ..VariantPatch::default()
    };
    Study {
        name: key.to_string(),
        base: base.clone(),
        axes: vec![Axis::Variant(vec![
            VariantPatch {
                name: "ISAAC-noprot".to_string(),
                method: Some(MethodKey::Unprotected),
                ..VariantPatch::default()
            },
            VariantPatch {
                name: "IWS-2".to_string(),
                method: Some(MethodKey::Iws),
                ..VariantPatch::default()
            },
            adc("HybAC-8b", 8),
            adc("HybAC-6b", 6),
            VariantPatch {
                name: "HybAC-6b-hq".to_string(),
                quant: Some(Some(QuantConfig::hybrid())),
                adc_bits: Some(Some(6)),
                ..VariantPatch::default()
            },
            VariantPatch {
                name: "HybACDi-4b".to_string(),
                cell: Some(CellModel::differential(0.5)),
                adc_bits: Some(Some(4)),
                ..VariantPatch::default()
            },
        ])],
    }
}

/// Fig. 11's device corners x wordline groups.
fn fig11(key: &str, base: &Scenario) -> Study {
    let corner = |name: &str, mult: f64, div: f64| VariantPatch {
        name: name.to_string(),
        cell: Some(fig11_scenario(mult, div)),
        ..VariantPatch::default()
    };
    Study {
        name: key.to_string(),
        base: base.clone(),
        axes: vec![
            Axis::Variant(vec![
                corner("Rb-s50", 1.0, 1.0),
                corner("2Rb-s25", 2.0, 2.0),
                corner("3Rb-s17", 3.0, 3.0),
                VariantPatch {
                    name: "HybridAC@16%".to_string(),
                    method: Some(MethodKey::Hybrid),
                    frac: Some(0.16),
                    cell: Some(fig11_scenario(1.0, 1.0)),
                    ..VariantPatch::default()
                },
            ]),
            Axis::Group(vec![16, 32, 64, 128]),
        ],
    }
}

// ---------------------------------------------------------------------------
// JSON plumbing (strict: unknown keys and mistyped values fail the parse)

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn check_keys(j: &Json, allowed: &[&str], what: &str) -> Result<()> {
    if let Json::Obj(m) = j {
        for key in m.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!("unknown {what} key '{key}' (allowed: {})", allowed.join(", "));
            }
        }
    }
    Ok(())
}

fn f64_val(j: &Json, what: &str) -> Result<f64> {
    j.as_f64().ok_or_else(|| anyhow::anyhow!("{what} is not a number"))
}

fn int_val(j: &Json, what: &str) -> Result<u64> {
    let v = f64_val(j, what)?;
    if v.fract() != 0.0 || !(0.0..9e15).contains(&v) {
        bail!("{what} is not a non-negative integer");
    }
    Ok(v as u64)
}

fn str_val<'a>(j: &'a Json, what: &str) -> Result<&'a str> {
    j.as_str().ok_or_else(|| anyhow::anyhow!("{what} is not a string"))
}

fn cell_to_json(c: &CellModel) -> Json {
    obj(vec![
        (
            "kind",
            Json::Str(
                match c.kind {
                    CellKind::Offset => "offset",
                    CellKind::Differential => "differential",
                }
                .to_string(),
            ),
        ),
        ("sigma", Json::Num(c.sigma)),
        (
            "r_ratio",
            if c.r_ratio.is_finite() { Json::Num(c.r_ratio) } else { Json::Null },
        ),
    ])
}

fn cell_from_json(j: &Json) -> Result<CellModel> {
    check_keys(j, &["kind", "sigma", "r_ratio"], "cell")?;
    let kind = match j.str_of("kind")? {
        "offset" => CellKind::Offset,
        "differential" => CellKind::Differential,
        k => bail!("unknown cell kind '{k}' (offset|differential)"),
    };
    let r_ratio = match j.get("r_ratio") {
        None | Some(Json::Null) => f64::INFINITY,
        Some(v) => f64_val(v, "'r_ratio'")?,
    };
    Ok(CellModel { kind, r_ratio, sigma: j.f64_of("sigma")? })
}

fn quant_to_json(q: &Option<QuantConfig>) -> Json {
    match q {
        None => Json::Null,
        Some(q) if *q == QuantConfig::uniform8() => Json::Str("uniform8".to_string()),
        Some(q) if *q == QuantConfig::hybrid() => Json::Str("hybrid".to_string()),
        Some(q) => obj(vec![
            ("analog_bits", Json::Num(q.analog_bits as f64)),
            ("digital_bits", Json::Num(q.digital_bits as f64)),
        ]),
    }
}

fn quant_from_json(j: &Json) -> Result<Option<QuantConfig>> {
    match j {
        Json::Null => Ok(None),
        Json::Str(s) => match s.as_str() {
            "uniform8" => Ok(Some(QuantConfig::uniform8())),
            "hybrid" => Ok(Some(QuantConfig::hybrid())),
            other => bail!("unknown quant name '{other}' (uniform8|hybrid, an object, or null)"),
        },
        Json::Obj(_) => {
            check_keys(j, &["analog_bits", "digital_bits"], "quant")?;
            Ok(Some(QuantConfig {
                analog_bits: int_val(j.req("analog_bits")?, "'analog_bits'")? as u32,
                digital_bits: int_val(j.req("digital_bits")?, "'digital_bits'")? as u32,
            }))
        }
        _ => bail!("'quant' must be a string, an object, or null"),
    }
}

fn adc_bits_from_json(j: &Json) -> Result<Option<u32>> {
    match j {
        Json::Null => Ok(None),
        _ => {
            let bits = int_val(j, "adc bits")?;
            if !(1..=32).contains(&bits) {
                bail!("adc bits must be in 1..=32, got {bits}");
            }
            Ok(Some(bits as u32))
        }
    }
}

fn adc_bits_to_json(b: &Option<u32>) -> Json {
    match b {
        Some(bits) => Json::Num(*bits as f64),
        None => Json::Null,
    }
}

fn variant_to_json(p: &VariantPatch) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(p.name.clone()));
    if let Some(method) = p.method {
        m.insert("method".to_string(), Json::Str(method.name().to_string()));
    }
    if let Some(frac) = p.frac {
        m.insert("frac".to_string(), Json::Num(frac));
    }
    if let Some(cell) = &p.cell {
        m.insert("cell".to_string(), cell_to_json(cell));
    }
    if let Some(sigma) = p.sigma {
        m.insert("sigma".to_string(), Json::Num(sigma));
    }
    if let Some(quant) = &p.quant {
        m.insert("quant".to_string(), quant_to_json(quant));
    }
    if let Some(bits) = &p.adc_bits {
        m.insert("adc_bits".to_string(), adc_bits_to_json(bits));
    }
    if let Some(group) = p.group {
        m.insert("group".to_string(), Json::Num(group as f64));
    }
    if let Some(seed) = p.seed {
        m.insert("seed".to_string(), Json::Num(seed as f64));
    }
    Json::Obj(m)
}

fn variant_from_json(j: &Json) -> Result<VariantPatch> {
    check_keys(
        j,
        &["name", "method", "frac", "cell", "sigma", "quant", "adc_bits", "group", "seed"],
        "variant",
    )?;
    let mut p = VariantPatch { name: j.str_of("name")?.to_string(), ..VariantPatch::default() };
    if let Some(v) = j.get("method") {
        p.method = Some(MethodKey::parse(str_val(v, "'method'")?)?);
    }
    if let Some(v) = j.get("frac") {
        p.frac = Some(f64_val(v, "'frac'")?);
    }
    if let Some(v) = j.get("cell") {
        p.cell = Some(cell_from_json(v).context("variant 'cell'")?);
    }
    if let Some(v) = j.get("sigma") {
        p.sigma = Some(f64_val(v, "'sigma'")?);
    }
    if let Some(v) = j.get("quant") {
        p.quant = Some(quant_from_json(v).context("variant 'quant'")?);
    }
    if let Some(v) = j.get("adc_bits") {
        p.adc_bits = Some(adc_bits_from_json(v).context("variant 'adc_bits'")?);
    }
    if let Some(v) = j.get("group") {
        p.group = Some(int_val(v, "'group'")? as usize);
    }
    if let Some(v) = j.get("seed") {
        p.seed = Some(int_val(v, "'seed'")?);
    }
    Ok(p)
}

fn axis_to_json(a: &Axis) -> Json {
    let key = Json::Str(a.key().to_string());
    match a {
        Axis::Frac(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect())),
        ]),
        Axis::Method(vs) => obj(vec![
            ("key", key),
            (
                "values",
                Json::Arr(vs.iter().map(|m| Json::Str(m.name().to_string())).collect()),
            ),
        ]),
        Axis::AdcBits(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(adc_bits_to_json).collect())),
        ]),
        Axis::Sigma(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect())),
        ]),
        Axis::Group(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(|&v| Json::Num(v as f64)).collect())),
        ]),
        Axis::Model(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(|v| Json::Str(v.clone())).collect())),
        ]),
        Axis::Seed(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(|&v| Json::Num(v as f64)).collect())),
        ]),
        Axis::Variant(vs) => obj(vec![
            ("key", key),
            ("values", Json::Arr(vs.iter().map(variant_to_json).collect())),
        ]),
        Axis::Search { values, params } => obj(vec![
            ("key", key),
            (
                "values",
                Json::Arr(values.iter().map(|v| Json::Str(v.name().to_string())).collect()),
            ),
            ("target_drop", Json::Num(params.target_drop)),
            ("max_frac", Json::Num(params.max_frac)),
            ("step", Json::Num(params.step)),
        ]),
    }
}

fn axis_from_json(j: &Json) -> Result<Axis> {
    let key = j.str_of("key")?;
    if key == "search" {
        check_keys(j, &["key", "values", "target_drop", "max_frac", "step"], "search axis")?;
    } else {
        check_keys(j, &["key", "values"], "axis")?;
    }
    let values = j.arr_of("values")?;
    let defaults = SearchParams::default();
    Ok(match key {
        "frac" => Axis::Frac(
            values
                .iter()
                .map(|v| f64_val(v, "frac value"))
                .collect::<Result<Vec<_>>>()?,
        ),
        "method" => Axis::Method(
            values
                .iter()
                .map(|v| MethodKey::parse(str_val(v, "method value")?))
                .collect::<Result<Vec<_>>>()?,
        ),
        "adc_bits" => Axis::AdcBits(
            values.iter().map(adc_bits_from_json).collect::<Result<Vec<_>>>()?,
        ),
        "sigma" => Axis::Sigma(
            values
                .iter()
                .map(|v| f64_val(v, "sigma value"))
                .collect::<Result<Vec<_>>>()?,
        ),
        "group" => Axis::Group(
            values
                .iter()
                .map(|v| int_val(v, "group value").map(|g| g as usize))
                .collect::<Result<Vec<_>>>()?,
        ),
        "model" => Axis::Model(
            values
                .iter()
                .map(|v| str_val(v, "model value").map(str::to_string))
                .collect::<Result<Vec<_>>>()?,
        ),
        "seed" => Axis::Seed(
            values
                .iter()
                .map(|v| int_val(v, "seed value"))
                .collect::<Result<Vec<_>>>()?,
        ),
        "variant" => Axis::Variant(
            values.iter().map(variant_from_json).collect::<Result<Vec<_>>>()?,
        ),
        "search" => Axis::Search {
            values: values
                .iter()
                .map(|v| SearchValue::parse(str_val(v, "search value")?))
                .collect::<Result<Vec<_>>>()?,
            params: SearchParams {
                target_drop: opt_f64(j, "target_drop", defaults.target_drop)?,
                max_frac: opt_f64(j, "max_frac", defaults.max_frac)?,
                step: opt_f64(j, "step", defaults.step)?,
            },
        },
        other => bail!(
            "unknown axis key '{other}' (allowed: frac, method, adc_bits, sigma, group, \
             model, seed, variant, search)"
        ),
    })
}

fn opt_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => f64_val(v, &format!("'{key}'")),
    }
}
