//! [`StudyRunner`]: execute a study's grid points across worker threads.
//!
//! Shared sub-results are memoized up front on the coordinating thread —
//! each model's artifact and dataset load once (shared via `Arc` into
//! every worker's [`Evaluator::from_parts`]), and the measured clean
//! accuracy per model (the anchor both the report and the `search` axis
//! target need) evaluates once. On the native backend every worker shares
//! *one* backend instance, so the fleet-wide [`CompiledGraphCache`]
//! compiles each `(model, group, polarity)` graph variant once for the
//! whole study no matter how many points or workers touch it; PJRT (not
//! `Send`) gets one engine per worker thread, exactly like the serve
//! fleet's [`BackendProvider::PerReplicaPjrt`] path.
//!
//! Determinism: a point's result depends only on its scenario (its own
//! seed forks the repeat RNG), never on scheduling, so a study renders
//! byte-identical reports at any worker count — `tests/study_props.rs`
//! pins 4 workers against 1.
//!
//! [`CompiledGraphCache`]: crate::exec::CompiledGraphCache
//! [`BackendProvider::PerReplicaPjrt`]: crate::exec::BackendProvider

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::eval::{Evaluator, ScenarioTiming};
use crate::exec::{BackendKind, BackendProvider, NativeConfig};
use crate::obs::trace;
use crate::runtime::{Artifact, DatasetBlob};
use crate::scenario::PreparedBaseCache;

use super::grid::StudyPoint;
use super::report::{PointResult, PointTiming, StudyReport};
use super::spec::{artifact_built, Study};

/// Executes studies: point expansion, per-model memoization, parallel
/// evaluation, report assembly.
pub struct StudyRunner {
    dir: PathBuf,
    workers: usize,
    /// Deterministic-prefix cache shared by every worker (and the clean
    /// anchors): sigma/seed/adc_bits-axis points split + quantize once.
    /// `None` = `--no-prepare-cache` (results are bit-identical either
    /// way; `tests/prepare_cache_props.rs` pins it).
    base_cache: Option<Arc<PreparedBaseCache>>,
}

impl StudyRunner {
    /// Runner over the given artifacts directory, auto-sized worker pool.
    pub fn new(dir: impl Into<PathBuf>) -> StudyRunner {
        StudyRunner {
            dir: dir.into(),
            workers: 0,
            base_cache: Some(Arc::new(PreparedBaseCache::new())),
        }
    }

    /// Fix the worker-thread count (0 = auto = available cores, capped at
    /// the point count). A pure throughput knob: reports are byte-identical
    /// at any value.
    pub fn with_workers(mut self, workers: usize) -> StudyRunner {
        self.workers = workers;
        self
    }

    /// Enable/disable the study-wide prepared-base cache (a pure
    /// throughput knob; on by default).
    pub fn with_prepare_cache(mut self, enabled: bool) -> StudyRunner {
        self.base_cache =
            if enabled { Some(Arc::new(PreparedBaseCache::new())) } else { None };
        self
    }

    /// Share an externally owned prepared-base cache (e.g. to inspect its
    /// hit/miss counts after the run, or to span several studies).
    pub fn with_base_cache(mut self, cache: Arc<PreparedBaseCache>) -> StudyRunner {
        self.base_cache = Some(cache);
        self
    }

    /// Run every point of `study` and collect the report. Models whose
    /// artifacts are not built are skipped with a loud notice (mirroring
    /// the old bench behavior on a partial `make artifacts`); any point
    /// that *runs* and fails fails the whole study.
    pub fn run(&self, study: &Study) -> Result<StudyReport> {
        let _span = trace::span_dyn("study", || format!("study {}", study.name));
        // tidy: allow(clock): whole-study wall time for the timing side
        // channel (timing_json), kept out of the byte-identical report
        let t0 = Instant::now();
        let kind = study.base.backend;
        let mut points = study.points()?;

        // -- artifact availability (memoized loads below) -------------------
        let mut models: Vec<String> = Vec::new();
        for p in &points {
            if !models.contains(&p.scenario.model) {
                models.push(p.scenario.model.clone());
            }
        }
        let mut skipped: Vec<String> = Vec::new();
        let mut built: Vec<String> = Vec::new();
        for model in models {
            if model == "synthetic" {
                if kind != BackendKind::Native {
                    bail!(
                        "the synthetic artifact has no exported HLO and runs on the native \
                         interpreter only — set the study base's backend to \"native\""
                    );
                }
                Artifact::materialize_synthetic(&self.dir)?;
            }
            if artifact_built(&self.dir, &model) {
                built.push(model);
            } else {
                eprintln!("[study] skipping {model}: artifact not built");
                skipped.push(model);
            }
        }
        points.retain(|p| built.contains(&p.scenario.model));

        // -- memoized shared sub-results ------------------------------------
        let mut arts: BTreeMap<String, Arc<Artifact>> = BTreeMap::new();
        let mut datas: BTreeMap<String, Arc<DatasetBlob>> = BTreeMap::new();
        for model in &built {
            let art = Arc::new(Artifact::load(&self.dir, model)?);
            if !datas.contains_key(&art.dataset) {
                datas.insert(
                    art.dataset.clone(),
                    Arc::new(DatasetBlob::load(&self.dir, &art.dataset)?),
                );
            }
            arts.insert(model.clone(), art);
        }

        let workers = self.resolve_workers(points.len());
        // with several points in flight, default the native kernels to one
        // thread each instead of oversubscribing every core per point
        // (results are bit-identical at any kernel thread count)
        let kernel_threads = if study.base.threads == 0 && workers > 1 {
            1
        } else {
            study.base.threads
        };
        let provider =
            BackendProvider::for_kind_with(kind, NativeConfig::with_threads(kernel_threads))?;

        // clean accuracy per model — the search target and the report
        // anchor — measured once per model and fanned out over the same
        // worker budget as the points (anchors are independent, and the
        // model-keyed map keeps the result scheduling-independent)
        let model_list: Vec<(String, Arc<Artifact>, Arc<DatasetBlob>)> = arts
            .iter()
            .map(|(model, art)| {
                let data = datas
                    .get(&art.dataset)
                    .expect("dataset preloaded for every built model")
                    .clone();
                (model.clone(), art.clone(), data)
            })
            .collect();
        let clean_workers = workers.min(model_list.len().max(1));
        let clean_slots: Vec<Mutex<Option<Result<f64>>>> =
            (0..model_list.len()).map(|_| Mutex::new(None)).collect();
        let next_model = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..clean_workers {
                scope.spawn(|| {
                    let backend = match provider.instantiate() {
                        Ok(b) => b,
                        Err(e) => {
                            // claim one slot for the error so the collector
                            // below surfaces it instead of hanging on None
                            let i = next_model.fetch_add(1, Ordering::Relaxed);
                            if i < model_list.len() {
                                *clean_slots[i].lock().unwrap() = Some(Err(
                                    e.context("instantiating a study worker backend"),
                                ));
                            }
                            return;
                        }
                    };
                    loop {
                        let i = next_model.fetch_add(1, Ordering::Relaxed);
                        if i >= model_list.len() {
                            return;
                        }
                        let (model, art, data) = &model_list[i];
                        let _span =
                            trace::span_dyn("study", || format!("clean-anchor {model}"));
                        let ev =
                            Evaluator::from_parts(art.clone(), data.clone(), backend.clone())
                                .with_base_cache(self.base_cache.clone());
                        let res = ev
                            .clean_accuracy(study.base.n_eval)
                            .with_context(|| format!("clean accuracy of '{model}'"));
                        *clean_slots[i].lock().unwrap() = Some(res);
                    }
                });
            }
        });
        let mut clean: BTreeMap<String, f64> = BTreeMap::new();
        for ((model, _, _), slot) in model_list.iter().zip(clean_slots) {
            match slot.into_inner().unwrap() {
                Some(res) => {
                    clean.insert(model.clone(), res?);
                }
                None => bail!(
                    "clean anchor for '{model}' was never evaluated (worker startup failed)"
                ),
            }
        }

        // -- parallel point execution ---------------------------------------
        let n = points.len();
        let next = AtomicUsize::new(0);
        // each slot gets (result, wall-clock seconds, worker id, prepare/
        // exec split); timing goes to the side channel, never into the
        // serialized report
        let slots: Vec<Mutex<Option<(PointResult, f64, usize, ScenarioTiming)>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next_worker = AtomicUsize::new(0);
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let worker_id = next_worker.fetch_add(1, Ordering::Relaxed);
                    let backend = match provider.instantiate() {
                        Ok(b) => b,
                        Err(e) => {
                            let mut f = failure.lock().unwrap();
                            if f.is_none() {
                                *f = Some(e.context("instantiating a study worker backend"));
                            }
                            return;
                        }
                    };
                    let mut evs: BTreeMap<String, Evaluator> = BTreeMap::new();
                    loop {
                        if failure.lock().unwrap().is_some() {
                            return;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return;
                        }
                        let point = &points[i];
                        let model = point.scenario.model.clone();
                        let ev = evs.entry(model.clone()).or_insert_with(|| {
                            let art = arts.get(&model).expect("artifact preloaded").clone();
                            let data = datas
                                .get(&art.dataset)
                                .expect("dataset preloaded")
                                .clone();
                            Evaluator::from_parts(art, data, backend.clone())
                                .with_base_cache(self.base_cache.clone())
                        });
                        // tidy: allow(clock): per-point wall time for the timing side
                        // channel (timing_json), kept out of the byte-identical report
                        let point_t0 = Instant::now();
                        let span = trace::span_dyn("study", || format!("point {}", point.id));
                        let outcome = run_point(ev, point, clean[&model]);
                        drop(span);
                        match outcome {
                            Ok((result, split)) => {
                                *slots[i].lock().unwrap() = Some((
                                    result,
                                    point_t0.elapsed().as_secs_f64(),
                                    worker_id,
                                    split,
                                ));
                            }
                            Err(e) => {
                                let mut f = failure.lock().unwrap();
                                if f.is_none() {
                                    *f = Some(e.context(format!("study point '{}'", point.id)));
                                }
                                return;
                            }
                        }
                    }
                });
            }
        });
        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        let mut results: Vec<PointResult> = Vec::with_capacity(n);
        let mut timing: Vec<PointTiming> = Vec::with_capacity(n);
        for slot in slots {
            let (result, secs, worker, split) =
                slot.into_inner().unwrap().expect("every point produced a result");
            timing.push(PointTiming {
                index: result.index,
                id: result.id.clone(),
                secs,
                worker,
                prepare_s: split.prepare_s,
                exec_s: split.exec_s,
            });
            results.push(result);
        }

        Ok(StudyReport {
            study: study.name.clone(),
            backend: kind,
            points: results,
            clean,
            skipped_models: skipped,
            workers,
            wall_s: t0.elapsed().as_secs_f64(),
            timing,
        })
    }

    fn resolve_workers(&self, n_points: usize) -> usize {
        let auto = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let w = if self.workers == 0 { auto() } else { self.workers };
        w.min(n_points.max(1)).max(1)
    }
}

/// Evaluate one grid point: a plain scenario run, or the Algorithm-1
/// crossing for `search`-axis points. Returns the result plus the
/// prepare/exec wall-clock split for the timing side channel.
fn run_point(
    ev: &Evaluator,
    point: &StudyPoint,
    clean: f64,
) -> Result<(PointResult, ScenarioTiming)> {
    let (frac, acc, searched, split) = match &point.search {
        Some(task) => {
            let target = clean - task.params.target_drop;
            let (frac, acc, split) = ev.search_protection_timed(
                |f| Evaluator::search_point(&point.scenario, task.split_at(f)),
                target,
                task.params.max_frac,
                task.params.step,
            )?;
            (frac, acc, true, split)
        }
        None => {
            let (acc, split) = ev.run_scenario_timed(&point.scenario)?;
            (point.scenario.protected_frac(), acc, false, split)
        }
    };
    Ok((
        PointResult {
            index: point.index,
            id: point.id.clone(),
            model: point.scenario.model.clone(),
            axes: point.axes.clone(),
            mean: acc.mean,
            std: acc.std,
            repeats: acc.repeats,
            clean,
            frac,
            searched,
        },
        split,
    ))
}
