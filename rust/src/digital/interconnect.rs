//! Interconnect models: the grid the HybridAC digital units use vs the
//! H-tree WAX uses (paper §3.2).
//!
//! The paper's argument for the grid: each unit mostly talks to its local
//! SRAM and its immediate neighbours; an H-tree makes even nearest-neighbour
//! traffic climb toward the root — distance as bad as log(chip width) — and
//! needs hierarchical muxing at every split plus a central controller,
//! which the grid eliminates.  This module quantifies exactly that claim:
//! hop counts, wire length, energy per transfer, and bisection bandwidth
//! for both topologies over the same unit array.

/// Position of a unit in a sqrt(N) x sqrt(N) array.
pub type Pos = (usize, usize);

/// Wire-energy constants (32 nm-class, per §3.2's "short interconnections").
pub const PJ_PER_MM_PER_BYTE: f64 = 0.2;
pub const UNIT_PITCH_MM: f64 = 0.21; // 6.81 mm^2 / 152 units, square-ish

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// 2-D mesh: neighbours are one pitch apart; routing is XY.
    Grid,
    /// Binary H-tree: every transfer routes up to the lowest common
    /// ancestor and back down; each split adds a mux traversal.
    HTree,
}

/// A unit array wired with one of the two topologies.
#[derive(Clone, Debug)]
pub struct Interconnect {
    pub topology: Topology,
    pub side: usize, // units per side
}

impl Interconnect {
    pub fn new(topology: Topology, n_units: usize) -> Self {
        let side = (n_units as f64).sqrt().ceil() as usize;
        Interconnect { topology, side: side.max(1) }
    }

    /// Number of link traversals for a transfer from `a` to `b`.
    pub fn hops(&self, a: Pos, b: Pos) -> usize {
        match self.topology {
            Topology::Grid => a.0.abs_diff(b.0) + a.1.abs_diff(b.1),
            Topology::HTree => {
                if a == b {
                    return 0;
                }
                // index units in row-major order; tree leaves = units.
                let ia = a.0 * self.side + a.1;
                let ib = b.0 * self.side + b.1;
                let n = self.side * self.side;
                let depth = (n as f64).log2().ceil() as usize;
                // distance = 2 * (depth - common prefix length)
                let diff = ia ^ ib;
                let msb = usize::BITS as usize - diff.leading_zeros() as usize;
                2 * msb.min(depth)
            }
        }
    }

    /// Physical wire length of the route (mm).
    pub fn wire_mm(&self, a: Pos, b: Pos) -> f64 {
        match self.topology {
            Topology::Grid => self.hops(a, b) as f64 * UNIT_PITCH_MM,
            Topology::HTree => {
                // each level's segment doubles in length going up the tree
                let h = self.hops(a, b);
                let up = h / 2;
                let mut len = 0.0;
                let mut seg = UNIT_PITCH_MM / 2.0;
                for _ in 0..up {
                    len += seg;
                    seg *= 2.0;
                }
                2.0 * len
            }
        }
    }

    /// Energy of moving `bytes` from `a` to `b` (pJ).
    pub fn transfer_pj(&self, a: Pos, b: Pos, bytes: usize) -> f64 {
        let mux_pj = match self.topology {
            Topology::Grid => 0.0,
            Topology::HTree => 0.05 * self.hops(a, b) as f64, // mux per split
        };
        self.wire_mm(a, b) * PJ_PER_MM_PER_BYTE * bytes as f64 + mux_pj * bytes as f64
    }

    /// Mean cost of the dominant traffic pattern — nearest-neighbour
    /// psum/activation exchange (paper: "each tile usually needs to access
    /// its local SRAM or its neighbors").
    pub fn neighbour_traffic_pj(&self, bytes: usize) -> f64 {
        let mut total = 0.0;
        let mut links = 0usize;
        for r in 0..self.side {
            for c in 0..self.side.saturating_sub(1) {
                total += self.transfer_pj((r, c), (r, c + 1), bytes);
                links += 1;
            }
        }
        total / links.max(1) as f64
    }

    /// Bisection bandwidth in links cut by a vertical midline (higher is
    /// better; the grid's advantage the paper cites from [14, 50]).
    pub fn bisection_links(&self) -> usize {
        match self.topology {
            Topology::Grid => self.side,
            Topology::HTree => 1, // a tree's bisection is its root link
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_neighbours_are_one_hop() {
        let g = Interconnect::new(Topology::Grid, 152);
        assert_eq!(g.hops((3, 4), (3, 5)), 1);
        assert_eq!(g.hops((3, 4), (5, 7)), 5);
    }

    #[test]
    fn htree_neighbour_distance_grows_with_array() {
        // the paper's complaint: adjacent units in different subtrees route
        // through up to log(width) levels
        let small = Interconnect::new(Topology::HTree, 16);
        let big = Interconnect::new(Topology::HTree, 1024);
        let mid_s = small.side / 2;
        let mid_b = big.side / 2;
        let hs = small.hops((0, mid_s - 1), (0, mid_s));
        let hb = big.hops((0, mid_b - 1), (0, mid_b));
        assert!(hb > hs, "H-tree neighbour hops should grow: {hs} -> {hb}");
    }

    #[test]
    fn grid_beats_htree_on_neighbour_energy() {
        let g = Interconnect::new(Topology::Grid, 152);
        let h = Interconnect::new(Topology::HTree, 152);
        let eg = g.neighbour_traffic_pj(24);
        let eh = h.neighbour_traffic_pj(24);
        assert!(eg < eh, "grid {eg} pJ vs H-tree {eh} pJ");
    }

    #[test]
    fn grid_has_wider_bisection() {
        let g = Interconnect::new(Topology::Grid, 152);
        let h = Interconnect::new(Topology::HTree, 152);
        assert!(g.bisection_links() > h.bisection_links());
    }

    #[test]
    fn zero_distance_is_free() {
        for t in [Topology::Grid, Topology::HTree] {
            let ic = Interconnect::new(t, 64);
            assert_eq!(ic.hops((2, 2), (2, 2)), 0);
            assert_eq!(ic.transfer_pj((2, 2), (2, 2), 24), 0.0);
        }
    }
}
