//! Cycle-level simulator of the HybridAC digital accelerator (§3.3, Fig. 5).
//!
//! One *unit* is the WAX-inspired tuple: a tiny 32-row x 24-byte SRAM
//! (1 activation row + 24 weight rows + 7 partial-sum rows), a 24-MAC
//! cluster, and three registers (activation / weight / psum) each split in
//! 4 channel partitions.  Units are connected in a grid (not an H-tree):
//! a unit talks only to its local SRAM and its grid neighbours.
//!
//! Dataflow per Fig. 5:
//!   * the activation SRAM row holds 6 consecutive inputs of 4 channels;
//!   * a weight SRAM row holds 3 successive weights of 4 channels for 2
//!     kernels; weights stay resident until fully reused;
//!   * each cycle the 24 MACs multiply and a 3-level adder tree folds 4
//!     products into each partial sum — 24 psum registers fill in 12
//!     cycles, then one SRAM write-back;
//!   * the next activation row loads while the current one computes
//!     (compute/communication overlap), so stalls only appear when a
//!     row's compute finishes before its successor loaded.

pub mod interconnect;
pub mod sim;

pub use sim::{DigitalSim, LayerWork, UnitStats};

/// Sustained MAC utilization of the Fig.-5 dataflow measured by the cycle
/// simulator on a representative conv workload (cached constant — see
/// `sim::measured_utilization`).  The adder tree retires 96 MACs per
/// 12-cycle batch against 288 issue slots, so this lands near 1/3 — the
/// same order as the paper's 434 GOPS/mm² over 6.81 mm² (~0.41 of peak).
pub fn sustained_utilization() -> f64 {
    sim::measured_utilization()
}
