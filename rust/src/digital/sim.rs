//! The digital-unit cycle simulator (Fig. 5 dataflow).

use std::sync::OnceLock;

/// SRAM geometry (§3.2: "1 row for activations, 24 rows for weights, and
/// 7 rows for partial sums" — 6x smaller than WAX).
pub const ACT_ROWS: usize = 1;
pub const WEIGHT_ROWS: usize = 24;
pub const PSUM_ROWS: usize = 7;
pub const MACS_PER_UNIT: usize = 24;
pub const CHANNELS_PER_ROW: usize = 4; // register partitions
pub const CLOCK_GHZ: f64 = 1.0;

/// Work one layer sends to the digital accelerator.
#[derive(Clone, Copy, Debug)]
pub struct LayerWork {
    /// number of MAC operations (digital-channel weights x output pixels)
    pub macs: u64,
    /// digital weights resident in the unit SRAMs
    pub weights: u64,
    /// activation values that must be streamed in
    pub activations: u64,
}

/// Per-unit occupancy/result statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitStats {
    pub cycles: u64,
    pub mac_ops: u64,
    pub stall_cycles: u64,
    pub sram_reads: u64,
    pub sram_writes: u64,
}

impl UnitStats {
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.mac_ops as f64 / (self.cycles as f64 * MACS_PER_UNIT as f64)
    }
}

/// Cycle simulation of `n_units` identical units draining one layer.
///
/// The model walks the Fig.-5 schedule instead of multiplying averages:
/// weights load once and stay resident; per activation row we pay a load
/// (hidden behind compute when the previous row's compute is long enough),
/// 12 compute cycles per 24-psum batch, and a write-back cycle per filled
/// psum row group.
#[derive(Clone, Debug)]
pub struct DigitalSim {
    pub n_units: usize,
}

impl DigitalSim {
    pub fn new(n_units: usize) -> Self {
        DigitalSim { n_units }
    }

    /// Simulate one layer; returns aggregate stats (worst unit's cycles —
    /// units run the same schedule on different output slices).
    pub fn run_layer(&self, work: &LayerWork) -> UnitStats {
        if work.macs == 0 {
            return UnitStats::default();
        }
        let macs_per_unit = work.macs.div_ceil(self.n_units as u64);
        let weights_per_unit = work.weights.div_ceil(self.n_units as u64);
        let acts_per_unit = work.activations.div_ceil(self.n_units as u64);

        let mut st = UnitStats::default();

        // one-time weight fill: SRAM row holds 24 weights (24 bytes, 3
        // weights x 4 channels x 2 kernels), written row by row; refills
        // needed when a layer's slice exceeds WEIGHT_ROWS rows.
        let weight_rows_needed = weights_per_unit.div_ceil(MACS_PER_UNIT as u64);
        let weight_fills = weight_rows_needed.div_ceil(WEIGHT_ROWS as u64);
        st.sram_writes += weight_rows_needed;
        st.cycles += weight_rows_needed; // 1 write / cycle

        // compute: each batch populates the 24 psum registers in 12 cycles;
        // each psum folds 4 products through the 3-level adder tree, so a
        // batch retires 24*4 = 96 useful MACs against 12*24 issue slots —
        // the schedule's inherent ~1/3 utilization (the tree, not the
        // multipliers, is the bottleneck), plus one write-back per batch.
        let batches = macs_per_unit.div_ceil((MACS_PER_UNIT * 4) as u64);
        let compute_cycles = batches * 12;
        let writeback_cycles = batches.div_ceil(PSUM_ROWS as u64); // row-granular
        st.cycles += compute_cycles + writeback_cycles;
        st.mac_ops += macs_per_unit;
        st.sram_writes += writeback_cycles;

        // activation streaming: a row (24 values) loads in 1 cycle and
        // overlaps with the 12 compute cycles; only the first load and any
        // refill burst beyond 1-per-12-cycles stalls.
        let act_rows = acts_per_unit.div_ceil(MACS_PER_UNIT as u64);
        st.sram_reads += act_rows + batches; // act row + weight row reads
        let hidden = compute_cycles / 12;
        let stalls = act_rows.saturating_sub(hidden) + 1 + weight_fills;
        st.stall_cycles += stalls;
        st.cycles += stalls;

        st
    }

    /// Wall-clock seconds for one layer at CLOCK_GHZ.
    pub fn layer_seconds(&self, work: &LayerWork) -> f64 {
        self.run_layer(work).cycles as f64 / (CLOCK_GHZ * 1e9)
    }

    /// Peak GOPS of the array (2 ops per MAC).
    pub fn peak_gops(&self) -> f64 {
        self.n_units as f64 * MACS_PER_UNIT as f64 * 2.0 * CLOCK_GHZ
    }

    /// Sustained GOPS on a workload = ops / time.
    pub fn sustained_gops(&self, work: &LayerWork) -> f64 {
        let st = self.run_layer(work);
        if st.cycles == 0 {
            return 0.0;
        }
        (st.mac_ops * 2) as f64 * self.n_units as f64
            / (st.cycles as f64 / (CLOCK_GHZ * 1e9))
            / 1e9
    }
}

/// Representative conv workload for utilization calibration: a mid-network
/// ResNet stage slice at 16% digital protection (the balanced operating
/// point, §5.4.2).
fn representative_work() -> LayerWork {
    let macs = (3 * 3 * 10 * 64 * 64) as u64; // 10 digital channels, 8x8 out
    LayerWork { macs, weights: 3 * 3 * 10 * 64, activations: 10 * 10 * 10 }
}

pub fn measured_utilization() -> f64 {
    static CACHE: OnceLock<f64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        let sim = DigitalSim::new(n_units_default());
        let st = sim.run_layer(&representative_work());
        st.utilization()
    })
}

pub fn n_units_default() -> usize {
    crate::hwmodel::components::DIGITAL_UNITS as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work(macs: u64) -> LayerWork {
        LayerWork { macs, weights: macs / 64, activations: macs / 90 }
    }

    #[test]
    fn zero_work_is_free() {
        let st = DigitalSim::new(152).run_layer(&LayerWork {
            macs: 0,
            weights: 0,
            activations: 0,
        });
        assert_eq!(st.cycles, 0);
    }

    #[test]
    fn cycles_scale_with_work() {
        let sim = DigitalSim::new(152);
        let small = sim.run_layer(&work(100_000)).cycles;
        let big = sim.run_layer(&work(1_000_000)).cycles;
        assert!(big > small * 5, "{big} vs {small}");
    }

    #[test]
    fn more_units_faster() {
        let w = work(2_000_000);
        let t1 = DigitalSim::new(64).layer_seconds(&w);
        let t2 = DigitalSim::new(152).layer_seconds(&w);
        assert!(t2 < t1);
    }

    #[test]
    fn utilization_below_one_above_zero() {
        let u = measured_utilization();
        assert!(u > 0.2 && u < 1.0, "utilization {u}");
    }

    #[test]
    fn sustained_below_peak() {
        let sim = DigitalSim::new(152);
        let w = work(5_000_000);
        assert!(sim.sustained_gops(&w) < sim.peak_gops());
    }
}
