//! Property tests (own mini-prop harness) on coordinator invariants that
//! don't need artifacts: ADC parameters, quantization, noise, digital sim,
//! mapping balance, metrics.

use hybridac::digital::{DigitalSim, LayerWork};
use hybridac::eval::prepare::adc_params;
use hybridac::noise::{CellKind, CellModel};
use hybridac::quantize::{fake_quant_val, qparams};
use hybridac::util::prop::{check, gen};
use hybridac::util::rng::Rng;

#[test]
fn prop_adc_lsb_scales_with_range() {
    check(
        "adc-lsb-monotone-in-range-frac",
        300,
        |r: &mut Rng| (gen::f64_in(0.05, 1.0)(r), gen::f64_in(0.05, 1.0)(r)),
        |&(f1, f2)| {
            let (lo, hi) = if f1 <= f2 { (f1, f2) } else { (f2, f1) };
            let (lsb_lo, _) = adc_params(100.0, 6, 128, lo, false);
            let (lsb_hi, _) = adc_params(100.0, 6, 128, hi, false);
            if lsb_lo <= lsb_hi + 1e-9 {
                Ok(())
            } else {
                Err(format!("lsb({lo})={lsb_lo} > lsb({hi})={lsb_hi}"))
            }
        },
    );
}

#[test]
fn prop_quant_error_half_lsb() {
    check(
        "fake-quant-error-bound",
        500,
        |r: &mut Rng| (gen::f64_in(-5.0, 5.0)(r), gen::usize_in(2, 10)(r)),
        |&(x, bits)| {
            let (scale, zp) = qparams(-5.0, 5.0, bits as u32);
            let y = fake_quant_val(x as f32, scale, zp, bits as u32);
            let err = (y - x as f32).abs();
            let half_lsb = 0.5 / scale + 1e-6;
            if err <= half_lsb {
                Ok(())
            } else {
                Err(format!("err {err} > half lsb {half_lsb} at {x}, {bits} bits"))
            }
        },
    );
}

#[test]
fn prop_noise_std_monotone_in_weight_magnitude() {
    check(
        "noise-std-monotone",
        300,
        |r: &mut Rng| (gen::f64_in(0.0, 1.0)(r), gen::f64_in(0.0, 1.0)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let cell = CellModel::analog_default();
            let s_lo = cell.weight_noise_std(lo, -1.0, 1.0);
            let s_hi = cell.weight_noise_std(hi, -1.0, 1.0);
            if s_lo <= s_hi + 1e-12 {
                Ok(())
            } else {
                Err(format!("std({lo})={s_lo} > std({hi})={s_hi}"))
            }
        },
    );
}

#[test]
fn prop_differential_never_noisier_than_offset() {
    check(
        "differential-pedestal-halved",
        300,
        gen::f64_in(-1.0, 1.0),
        |&w| {
            let off = CellModel { kind: CellKind::Offset, r_ratio: 10.0, sigma: 0.5 };
            let dif = CellModel { kind: CellKind::Differential, r_ratio: 10.0, sigma: 0.5 };
            let so = off.weight_noise_std(w, -1.0, 1.0);
            let sd = dif.weight_noise_std(w, -1.0, 1.0);
            if sd <= so + 1e-12 {
                Ok(())
            } else {
                Err(format!("diff {sd} > offset {so} at w={w}"))
            }
        },
    );
}

#[test]
fn prop_digital_sim_work_conservation() {
    check(
        "digital-sim-macs-conserved",
        200,
        gen::usize_in(1, 5_000_000),
        |&macs| {
            let sim = DigitalSim::new(152);
            let st = sim.run_layer(&LayerWork {
                macs: macs as u64,
                weights: (macs / 64) as u64,
                activations: (macs / 90) as u64,
            });
            let per_unit = (macs as u64).div_ceil(152);
            if st.mac_ops == per_unit {
                Ok(())
            } else {
                Err(format!("mac_ops {} != per-unit work {per_unit}", st.mac_ops))
            }
        },
    );
}

#[test]
fn prop_digital_sim_cycles_monotone() {
    check(
        "digital-sim-monotone",
        150,
        |r: &mut Rng| (gen::usize_in(1, 2_000_000)(r), gen::usize_in(1, 2_000_000)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let sim = DigitalSim::new(64);
            let mk = |m: usize| LayerWork {
                macs: m as u64,
                weights: (m / 64) as u64,
                activations: (m / 90) as u64,
            };
            let c_lo = sim.run_layer(&mk(lo)).cycles;
            let c_hi = sim.run_layer(&mk(hi)).cycles;
            if c_lo <= c_hi {
                Ok(())
            } else {
                Err(format!("cycles({lo})={c_lo} > cycles({hi})={c_hi}"))
            }
        },
    );
}

#[test]
fn prop_rng_normal_tail_bounds() {
    check(
        "rng-normal-bounded-tails",
        20,
        gen::usize_in(0, 1_000_000),
        |&seed| {
            let mut rng = Rng::new(seed as u64);
            let n = 10_000;
            let extreme = (0..n).filter(|_| rng.normal().abs() > 4.0).count();
            // P(|Z|>4) ~ 6e-5; allow a generous bound
            if extreme <= 8 {
                Ok(())
            } else {
                Err(format!("{extreme} samples beyond 4 sigma of {n}"))
            }
        },
    );
}

#[test]
fn prop_json_roundtrip_numbers() {
    use hybridac::util::json::Json;
    check(
        "json-number-roundtrip",
        300,
        gen::f64_in(-1e9, 1e9),
        |&x| {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            match back {
                Json::Num(y) if (y - x).abs() <= 1e-6 * x.abs().max(1.0) => Ok(()),
                other => Err(format!("{x} -> {text} -> {other:?}")),
            }
        },
    );
}
