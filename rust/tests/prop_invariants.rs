//! Property tests (own mini-prop harness) on coordinator invariants that
//! don't need on-disk artifacts: ADC parameters, quantization, noise,
//! digital sim, selection monotonicity (over `Artifact::synthetic`), and
//! the `Scenario` JSON round trip.

use hybridac::digital::{DigitalSim, LayerWork};
use hybridac::eval::prepare::adc_params;
use hybridac::noise::{CellKind, CellModel};
use hybridac::quantize::{fake_quant_val, qparams, QuantConfig};
use hybridac::runtime::Artifact;
use hybridac::scenario::{PerturbSpec, ReadoutSpec, Scenario, SplitSpec};
use hybridac::selection::{IwsMasks, Partition};
use hybridac::util::prop::{check, gen};
use hybridac::util::rng::Rng;

#[test]
fn prop_adc_lsb_scales_with_range() {
    check(
        "adc-lsb-monotone-in-range-frac",
        300,
        |r: &mut Rng| (gen::f64_in(0.05, 1.0)(r), gen::f64_in(0.05, 1.0)(r)),
        |&(f1, f2)| {
            let (lo, hi) = if f1 <= f2 { (f1, f2) } else { (f2, f1) };
            let (lsb_lo, _) = adc_params(100.0, 6, 128, lo, false);
            let (lsb_hi, _) = adc_params(100.0, 6, 128, hi, false);
            if lsb_lo <= lsb_hi + 1e-9 {
                Ok(())
            } else {
                Err(format!("lsb({lo})={lsb_lo} > lsb({hi})={lsb_hi}"))
            }
        },
    );
}

#[test]
fn prop_quant_error_half_lsb() {
    check(
        "fake-quant-error-bound",
        500,
        |r: &mut Rng| (gen::f64_in(-5.0, 5.0)(r), gen::usize_in(2, 10)(r)),
        |&(x, bits)| {
            let (scale, zp) = qparams(-5.0, 5.0, bits as u32);
            let y = fake_quant_val(x as f32, scale, zp, bits as u32);
            let err = (y - x as f32).abs();
            let half_lsb = 0.5 / scale + 1e-6;
            if err <= half_lsb {
                Ok(())
            } else {
                Err(format!("err {err} > half lsb {half_lsb} at {x}, {bits} bits"))
            }
        },
    );
}

#[test]
fn prop_noise_std_monotone_in_weight_magnitude() {
    check(
        "noise-std-monotone",
        300,
        |r: &mut Rng| (gen::f64_in(0.0, 1.0)(r), gen::f64_in(0.0, 1.0)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let cell = CellModel::analog_default();
            let s_lo = cell.weight_noise_std(lo, -1.0, 1.0);
            let s_hi = cell.weight_noise_std(hi, -1.0, 1.0);
            if s_lo <= s_hi + 1e-12 {
                Ok(())
            } else {
                Err(format!("std({lo})={s_lo} > std({hi})={s_hi}"))
            }
        },
    );
}

#[test]
fn prop_differential_never_noisier_than_offset() {
    check(
        "differential-pedestal-halved",
        300,
        gen::f64_in(-1.0, 1.0),
        |&w| {
            let off = CellModel { kind: CellKind::Offset, r_ratio: 10.0, sigma: 0.5 };
            let dif = CellModel { kind: CellKind::Differential, r_ratio: 10.0, sigma: 0.5 };
            let so = off.weight_noise_std(w, -1.0, 1.0);
            let sd = dif.weight_noise_std(w, -1.0, 1.0);
            if sd <= so + 1e-12 {
                Ok(())
            } else {
                Err(format!("diff {sd} > offset {so} at w={w}"))
            }
        },
    );
}

#[test]
fn prop_digital_sim_work_conservation() {
    check(
        "digital-sim-macs-conserved",
        200,
        gen::usize_in(1, 5_000_000),
        |&macs| {
            let sim = DigitalSim::new(152);
            let st = sim.run_layer(&LayerWork {
                macs: macs as u64,
                weights: (macs / 64) as u64,
                activations: (macs / 90) as u64,
            });
            let per_unit = (macs as u64).div_ceil(152);
            if st.mac_ops == per_unit {
                Ok(())
            } else {
                Err(format!("mac_ops {} != per-unit work {per_unit}", st.mac_ops))
            }
        },
    );
}

#[test]
fn prop_digital_sim_cycles_monotone() {
    check(
        "digital-sim-monotone",
        150,
        |r: &mut Rng| (gen::usize_in(1, 2_000_000)(r), gen::usize_in(1, 2_000_000)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let sim = DigitalSim::new(64);
            let mk = |m: usize| LayerWork {
                macs: m as u64,
                weights: (m / 64) as u64,
                activations: (m / 90) as u64,
            };
            let c_lo = sim.run_layer(&mk(lo)).cycles;
            let c_hi = sim.run_layer(&mk(hi)).cycles;
            if c_lo <= c_hi {
                Ok(())
            } else {
                Err(format!("cycles({lo})={c_lo} > cycles({hi})={c_hi}"))
            }
        },
    );
}

#[test]
fn prop_rng_normal_tail_bounds() {
    check(
        "rng-normal-bounded-tails",
        20,
        gen::usize_in(0, 1_000_000),
        |&seed| {
            let mut rng = Rng::new(seed as u64);
            let n = 10_000;
            let extreme = (0..n).filter(|_| rng.normal().abs() > 4.0).count();
            // P(|Z|>4) ~ 6e-5; allow a generous bound
            if extreme <= 8 {
                Ok(())
            } else {
                Err(format!("{extreme} samples beyond 4 sigma of {n}"))
            }
        },
    );
}

/// `Partition::for_fraction`: the achieved protected fraction is
/// nondecreasing in the requested fraction and never exceeds 1.0 (it may
/// exceed the *request* — pinned layers and whole-channel granularity —
/// but growing the request can never shrink the selection).
#[test]
fn prop_partition_protected_frac_monotone_and_bounded() {
    let art = Artifact::synthetic(0xA11CE);
    check(
        "partition-monotone-bounded",
        120,
        |r: &mut Rng| (gen::f64_in(0.0, 1.0)(r), gen::f64_in(0.0, 1.0)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let p_lo = Partition::for_fraction(&art, lo);
            let p_hi = Partition::for_fraction(&art, hi);
            if p_lo.protected_frac > p_hi.protected_frac + 1e-12 {
                return Err(format!(
                    "frac({lo})={} > frac({hi})={}",
                    p_lo.protected_frac, p_hi.protected_frac
                ));
            }
            if p_hi.protected_frac > 1.0 + 1e-12 {
                return Err(format!("achieved {} exceeds 1.0", p_hi.protected_frac));
            }
            // the pinned floor always holds
            let floor = art.pinned_weights as f64 / art.total_weights as f64;
            if p_lo.protected_frac + 1e-12 < floor {
                return Err(format!("achieved {} below pinned floor {floor}", p_lo.protected_frac));
            }
            Ok(())
        },
    );
}

/// Same invariants for the IWS per-weight baseline.
#[test]
fn prop_iws_protected_frac_monotone_and_bounded() {
    let art = Artifact::synthetic(0xB0B);
    check(
        "iws-monotone-bounded",
        120,
        |r: &mut Rng| (gen::f64_in(0.0, 1.0)(r), gen::f64_in(0.0, 1.0)(r)),
        |&(a, b)| {
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let m_lo = IwsMasks::for_fraction(&art, lo);
            let m_hi = IwsMasks::for_fraction(&art, hi);
            if m_lo.protected_frac > m_hi.protected_frac + 1e-12 {
                return Err(format!(
                    "frac({lo})={} > frac({hi})={}",
                    m_lo.protected_frac, m_hi.protected_frac
                ));
            }
            if m_hi.protected_frac > 1.0 + 1e-12 {
                return Err(format!("achieved {} exceeds 1.0", m_hi.protected_frac));
            }
            Ok(())
        },
    );
}

fn random_scenario(r: &mut Rng) -> Scenario {
    let split = match r.below(3) {
        0 => SplitSpec::Channels { frac: r.next_f64() },
        1 => SplitSpec::Iws { frac: r.next_f64() },
        _ => SplitSpec::AllAnalog,
    };
    let quant = match r.below(3) {
        0 => None,
        1 => Some(QuantConfig::uniform8()),
        _ => Some(QuantConfig { analog_bits: 2 + r.below(9) as u32, digital_bits: 8 }),
    };
    let mut perturb = Vec::new();
    if r.below(2) == 0 {
        let cell = match r.below(3) {
            0 => CellModel::offset(r.next_f64()),
            1 => CellModel::differential(r.next_f64()),
            _ => CellModel::relative(r.next_f64()), // infinite R-ratio path
        };
        perturb.push(PerturbSpec::AnalogVariation { cell });
    }
    if r.below(2) == 0 {
        perturb.push(PerturbSpec::DigitalVariation { sigma: r.next_f64() * 0.5 });
    }
    if r.below(2) == 0 {
        perturb.push(PerturbSpec::StuckAt { rate: r.next_f64() * 0.01 });
    }
    if r.below(2) == 0 {
        perturb.push(PerturbSpec::Drift {
            t_seconds: 1.0 + r.next_f64() * 1e6,
            nu: r.next_f64() * 0.1,
            nu_sigma: r.next_f64() * 0.05,
        });
    }
    let readout = if r.below(2) == 0 {
        ReadoutSpec::Adc { bits: 2 + r.below(9) as u32 }
    } else {
        ReadoutSpec::Ideal
    };
    Scenario {
        name: format!("prop-{}", r.below(1000)),
        model: "resnet18m_c10s".to_string(),
        split,
        quant,
        perturb,
        readout,
        group: [16, 32, 64, 128][r.below(4)],
        n_eval: 1 + r.below(2000),
        repeats: 1 + r.below(8),
        seed: r.next_u64() >> 11, // < 2^53: exact through a JSON number
        backend: if r.below(2) == 0 {
            hybridac::exec::BackendKind::Native
        } else {
            hybridac::exec::BackendKind::default()
        },
        threads: [0usize, 1, 2, 8][r.below(4)],
    }
}

/// parse(serialize(s)) is the identity on scenarios, and the serialized
/// text is a fixed point (canonical key order, shortest-round-trip floats).
#[test]
fn scenario_json_round_trip() {
    let mut rng = Rng::new(0x5CE7A);
    for case in 0..300 {
        let sc = random_scenario(&mut rng);
        let text = sc.to_json().to_string();
        let back = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: parse failed: {e}\n{text}"));
        assert_eq!(sc, back, "case {case}: round trip changed the scenario\n{text}");
        assert_eq!(
            text,
            back.to_json().to_string(),
            "case {case}: serialization is not a fixed point"
        );
    }
}

#[test]
fn prop_json_roundtrip_numbers() {
    use hybridac::util::json::Json;
    check(
        "json-number-roundtrip",
        300,
        gen::f64_in(-1e9, 1e9),
        |&x| {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            match back {
                Json::Num(y) if (y - x).abs() <= 1e-6 * x.abs().max(1.0) => Ok(()),
                other => Err(format!("{x} -> {text} -> {other:?}")),
            }
        },
    );
}
