//! Kernel property tests: the packed, register-tiled, thread-sharded
//! micro-kernels must produce *exactly* the scalar reference's output —
//! over randomized shapes (including MR/NR/group tails), wordline group
//! sizes, ADC lsb/clip settings, activation sparsity (the reference's
//! zero-skip path), and thread counts ∈ {1, 4}.
//!
//! "Exact" means element-wise `==` on the f32 payloads: the kernels
//! replicate the reference's per-element accumulation order, so every bit
//! of every partial sum, ADC rounding, and clamp agrees. This closes the
//! ROADMAP follow-up "property-test it against `crossbar_matmul_numpy` via
//! a shared fixture": `reference_*` is the rust twin of
//! `kernels/ref.py::crossbar_matmul_ref`, which the python pytest pins
//! against numpy.

use hybridac::exec::native::kernels::{crossbar_matmul_packed, PackedMatrix};
use hybridac::exec::native::reference::{reference_crossbar_matmul, reference_matmul};
use hybridac::exec::native::{crossbar_matmul, matmul};
use hybridac::tensor::Tensor;
use hybridac::util::rng::Rng;

/// Random matrix with a controllable fraction of *exact* zeros, so the
/// reference's zero-activation skip and the kernel's multiply-through
/// disagree on as many terms as possible (they must still match).
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize, zero_frac: f64) -> Tensor {
    let mut data = vec![0.0f32; rows * cols];
    for v in data.iter_mut() {
        if rng.next_f64() >= zero_frac {
            *v = rng.normal_f32();
        }
    }
    Tensor::new(vec![rows, cols], data)
}

fn random_case(rng: &mut Rng) -> (usize, usize, usize, usize, f32, f32) {
    let m = 1 + rng.below(40);
    let k = 1 + rng.below(96);
    let n = 1 + rng.below(48);
    // group sizes: unit, sub-K with a ragged tail, exactly K, and past K
    let group = match rng.below(5) {
        0 => 1,
        1 => 2 + rng.below(7),
        2 => 16,
        3 => k,
        _ => 128,
    };
    let (lsb, clip) = match rng.below(4) {
        0 => (-1.0f32, 1.0f32), // ideal readout
        1 => (0.25, 4.0),
        2 => (0.03125, 0.5), // aggressive clipping
        _ => (0.1, 100.0),
    };
    (m, k, n, group, lsb, clip)
}

#[test]
fn packed_crossbar_equals_scalar_reference_exactly() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..150 {
        let (m, k, n, group, lsb, clip) = random_case(&mut rng);
        let x = random_matrix(&mut rng, m, k, 0.3);
        let w = random_matrix(&mut rng, k, n, 0.1);
        let reference = reference_crossbar_matmul(&x, &w, lsb, clip, group);
        let packed = PackedMatrix::pack(&w.data, k, n);
        for &threads in &[1usize, 4] {
            let mut out = vec![f32::NAN; m * n];
            crossbar_matmul_packed(&x.data, m, k, &packed, lsb, clip, group, &mut out, threads);
            assert_eq!(
                out, reference.data,
                "case {case}: m={m} k={k} n={n} group={group} lsb={lsb} clip={clip} \
                 threads={threads}"
            );
        }
        // the public Tensor wrapper is the same kernel
        let wrapped = crossbar_matmul(&x, &w, lsb, clip, group);
        assert_eq!(wrapped.shape, vec![m, n]);
        assert_eq!(wrapped.data, reference.data, "case {case}: wrapper diverged");
    }
}

#[test]
fn packed_matmul_equals_scalar_reference_exactly() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..150 {
        let (m, k, n, _, _, _) = random_case(&mut rng);
        let x = random_matrix(&mut rng, m, k, 0.5);
        let w = random_matrix(&mut rng, k, n, 0.0);
        let reference = reference_matmul(&x, &w);
        // the digital path is the crossbar kernel with ideal readout over
        // one group spanning all of K — at both thread counts
        let packed = PackedMatrix::pack(&w.data, k, n);
        for &threads in &[1usize, 4] {
            let mut out = vec![f32::NAN; m * n];
            crossbar_matmul_packed(&x.data, m, k, &packed, -1.0, 1.0, k, &mut out, threads);
            assert_eq!(out, reference.data, "case {case}: m={m} k={k} n={n} threads={threads}");
        }
        let wrapped = matmul(&x, &w);
        assert_eq!(wrapped.data, reference.data, "case {case}: wrapper diverged");
    }
}

#[test]
fn degenerate_shapes_match_the_reference() {
    // single elements, all-zero activations, group far past K, row/column
    // counts straddling the MR/NR tile edges
    let mut rng = Rng::new(7);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (1, 1, 9),   // one row, NR tail
        (4, 3, 8),   // exact MR x NR tile
        (5, 3, 8),   // MR tail row
        (3, 7, 17),  // everything ragged
        (33, 2, 1),  // single column
    ] {
        let x = random_matrix(&mut rng, m, k, 0.2);
        let w = random_matrix(&mut rng, k, n, 0.2);
        for &(lsb, clip) in &[(-1.0f32, 1.0f32), (0.5, 2.0)] {
            for &group in &[1usize, 2, 1000] {
                let reference = reference_crossbar_matmul(&x, &w, lsb, clip, group);
                let got = crossbar_matmul(&x, &w, lsb, clip, group);
                assert_eq!(got.data, reference.data, "m={m} k={k} n={n} group={group}");
            }
        }
        // all-zero activations: the reference skips every term
        let zx = Tensor::zeros(vec![m, k]);
        assert_eq!(
            crossbar_matmul(&zx, &w, 0.5, 2.0, 2).data,
            reference_crossbar_matmul(&zx, &w, 0.5, 2.0, 2).data,
            "all-zero x, m={m} k={k} n={n}"
        );
        assert_eq!(matmul(&zx, &w).data, reference_matmul(&zx, &w).data);
    }
}
