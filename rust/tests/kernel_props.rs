//! Kernel property tests: the packed, register-tiled, thread-sharded
//! micro-kernels must produce *exactly* the scalar reference's output —
//! over randomized shapes (including MR/NR/group tails), wordline group
//! sizes, ADC lsb/clip settings, activation sparsity (the reference's
//! zero-skip path), and thread counts ∈ {1, 4}.
//!
//! "Exact" means element-wise `==` on the f32 payloads: the kernels
//! replicate the reference's per-element accumulation order, so every bit
//! of every partial sum, ADC rounding, and clamp agrees. This closes the
//! ROADMAP follow-up "property-test it against `crossbar_matmul_numpy` via
//! a shared fixture": `reference_*` is the rust twin of
//! `kernels/ref.py::crossbar_matmul_ref`, which the python pytest pins
//! against numpy.

use hybridac::exec::native::kernels::{
    crossbar_matmul_packed, crossbar_matmul_packed_with, KernelKind, KernelPath, KernelSel,
    PackedMatrix,
};
use hybridac::exec::native::reference::{
    reference_crossbar_int, reference_crossbar_matmul, reference_matmul,
};
use hybridac::exec::native::{crossbar_matmul, matmul};
use hybridac::tensor::Tensor;
use hybridac::util::rng::Rng;

/// Random matrix with a controllable fraction of *exact* zeros, so the
/// reference's zero-activation skip and the kernel's multiply-through
/// disagree on as many terms as possible (they must still match).
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize, zero_frac: f64) -> Tensor {
    let mut data = vec![0.0f32; rows * cols];
    for v in data.iter_mut() {
        if rng.next_f64() >= zero_frac {
            *v = rng.normal_f32();
        }
    }
    Tensor::new(vec![rows, cols], data)
}

fn random_case(rng: &mut Rng) -> (usize, usize, usize, usize, f32, f32) {
    let m = 1 + rng.below(40);
    let k = 1 + rng.below(96);
    let n = 1 + rng.below(48);
    // group sizes: unit, sub-K with a ragged tail, exactly K, and past K
    let group = match rng.below(5) {
        0 => 1,
        1 => 2 + rng.below(7),
        2 => 16,
        3 => k,
        _ => 128,
    };
    let (lsb, clip) = match rng.below(4) {
        0 => (-1.0f32, 1.0f32), // ideal readout
        1 => (0.25, 4.0),
        2 => (0.03125, 0.5), // aggressive clipping
        _ => (0.1, 100.0),
    };
    (m, k, n, group, lsb, clip)
}

#[test]
fn packed_crossbar_equals_scalar_reference_exactly() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..150 {
        let (m, k, n, group, lsb, clip) = random_case(&mut rng);
        let x = random_matrix(&mut rng, m, k, 0.3);
        let w = random_matrix(&mut rng, k, n, 0.1);
        let reference = reference_crossbar_matmul(&x, &w, lsb, clip, group);
        let packed = PackedMatrix::pack(&w.data, k, n);
        for &threads in &[1usize, 4] {
            let mut out = vec![f32::NAN; m * n];
            crossbar_matmul_packed(&x.data, m, k, &packed, lsb, clip, group, &mut out, threads);
            assert_eq!(
                out, reference.data,
                "case {case}: m={m} k={k} n={n} group={group} lsb={lsb} clip={clip} \
                 threads={threads}"
            );
        }
        // the public Tensor wrapper is the same kernel
        let wrapped = crossbar_matmul(&x, &w, lsb, clip, group);
        assert_eq!(wrapped.shape, vec![m, n]);
        assert_eq!(wrapped.data, reference.data, "case {case}: wrapper diverged");
    }
}

#[test]
fn packed_matmul_equals_scalar_reference_exactly() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..150 {
        let (m, k, n, _, _, _) = random_case(&mut rng);
        let x = random_matrix(&mut rng, m, k, 0.5);
        let w = random_matrix(&mut rng, k, n, 0.0);
        let reference = reference_matmul(&x, &w);
        // the digital path is the crossbar kernel with ideal readout over
        // one group spanning all of K — at both thread counts
        let packed = PackedMatrix::pack(&w.data, k, n);
        for &threads in &[1usize, 4] {
            let mut out = vec![f32::NAN; m * n];
            crossbar_matmul_packed(&x.data, m, k, &packed, -1.0, 1.0, k, &mut out, threads);
            assert_eq!(out, reference.data, "case {case}: m={m} k={k} n={n} threads={threads}");
        }
        let wrapped = matmul(&x, &w);
        assert_eq!(wrapped.data, reference.data, "case {case}: wrapper diverged");
    }
}

#[test]
fn degenerate_shapes_match_the_reference() {
    // single elements, all-zero activations, group far past K, row/column
    // counts straddling the MR/NR tile edges
    let mut rng = Rng::new(7);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (1, 1, 9),   // one row, NR tail
        (4, 3, 8),   // exact MR x NR tile
        (5, 3, 8),   // MR tail row
        (3, 7, 17),  // everything ragged
        (33, 2, 1),  // single column
    ] {
        let x = random_matrix(&mut rng, m, k, 0.2);
        let w = random_matrix(&mut rng, k, n, 0.2);
        for &(lsb, clip) in &[(-1.0f32, 1.0f32), (0.5, 2.0)] {
            for &group in &[1usize, 2, 1000] {
                let reference = reference_crossbar_matmul(&x, &w, lsb, clip, group);
                let got = crossbar_matmul(&x, &w, lsb, clip, group);
                assert_eq!(got.data, reference.data, "m={m} k={k} n={n} group={group}");
            }
        }
        // all-zero activations: the reference skips every term
        let zx = Tensor::zeros(vec![m, k]);
        assert_eq!(
            crossbar_matmul(&zx, &w, 0.5, 2.0, 2).data,
            reference_crossbar_matmul(&zx, &w, 0.5, 2.0, 2).data,
            "all-zero x, m={m} k={k} n={n}"
        );
        assert_eq!(matmul(&zx, &w).data, reference_matmul(&zx, &w).data);
    }
}

/// A matrix whose every value sits exactly on the `2^-7` i16 grid
/// (|q| <= 127), with a controllable fraction of exact zeros — the operand
/// class the integer ADC-domain path engages on.
fn grid_matrix(rng: &mut Rng, rows: usize, cols: usize, zero_frac: f64) -> Tensor {
    let mut data = vec![0.0f32; rows * cols];
    for v in data.iter_mut() {
        if rng.next_f64() >= zero_frac {
            *v = ((rng.below(255) as i32) - 127) as f32 / 128.0;
        }
    }
    Tensor::new(vec![rows, cols], data)
}

#[test]
fn forced_simd_is_bit_identical_to_forced_scalar() {
    // the explicit-intrinsics kernel against the scalar tile, over
    // randomized shapes/groups/lsb/clip/sparsity and threads {1, 4} —
    // on hosts without SIMD this degenerates to scalar-vs-scalar (still a
    // valid, if vacuous, equality; CI pins an AVX2 runner)
    let mut rng = Rng::new(0x51AD);
    let simd = KernelSel::resolve(KernelKind::Simd);
    let scalar = KernelSel::resolve(KernelKind::Scalar);
    for case in 0..150 {
        let (m, k, n, group, lsb, clip) = random_case(&mut rng);
        let x = random_matrix(&mut rng, m, k, 0.3);
        let w = random_matrix(&mut rng, k, n, 0.1);
        let packed = PackedMatrix::pack(&w.data, k, n);
        let mut want = vec![f32::NAN; m * n];
        crossbar_matmul_packed_with(&x.data, m, k, &packed, lsb, clip, group, &mut want, 1, scalar);
        for &threads in &[1usize, 4] {
            let mut got = vec![f32::NAN; m * n];
            let path = crossbar_matmul_packed_with(
                &x.data, m, k, &packed, lsb, clip, group, &mut got, threads, simd,
            );
            assert_ne!(path, KernelPath::Int, "f32-only packing must never go int");
            assert_eq!(
                got, want,
                "case {case}: m={m} k={k} n={n} group={group} lsb={lsb} clip={clip} \
                 threads={threads}"
            );
        }
    }
}

#[test]
fn int_path_is_exact_on_representable_operands() {
    // operands on exact power-of-two grids: the int oracle must engage,
    // match the f32 reference bit-for-bit, and the production dispatch
    // must take the int path and agree — at threads {1, 4}
    let mut rng = Rng::new(0x1A7E);
    let int = KernelSel::resolve(KernelKind::Int);
    for case in 0..100 {
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(96);
        let n = 1 + rng.below(48);
        // even groups (plus the spans-all-of-K case) engage; group=128
        // exceeds most sampled k, exercising the single-group path
        let group = match rng.below(4) {
            0 => 2 + 2 * rng.below(8),
            1 => 16,
            2 => 128,
            _ => k + (k & 1),
        };
        let (lsb, clip) = match rng.below(3) {
            0 => (-1.0f32, 1.0f32),
            1 => (0.25, 4.0),
            _ => (0.05, 8.0),
        };
        let x = grid_matrix(&mut rng, m, k, 0.2);
        let w = grid_matrix(&mut rng, k, n, 0.1);
        let reference = reference_crossbar_matmul(&x, &w, lsb, clip, group);
        let int_ref = reference_crossbar_int(&x, &w, lsb, clip, group)
            .expect("grid operands with an even group must admit the int oracle");
        assert_eq!(
            int_ref.data, reference.data,
            "case {case}: int oracle diverged (m={m} k={k} n={n} group={group} lsb={lsb})"
        );
        let packed = PackedMatrix::pack_with(&w.data, k, n, true);
        for &threads in &[1usize, 4] {
            let mut got = vec![f32::NAN; m * n];
            let path = crossbar_matmul_packed_with(
                &x.data, m, k, &packed, lsb, clip, group, &mut got, threads, int,
            );
            assert_eq!(path, KernelPath::Int, "case {case}: int path must engage");
            assert_eq!(
                got, reference.data,
                "case {case}: m={m} k={k} n={n} group={group} lsb={lsb} threads={threads}"
            );
        }
    }
}

#[test]
fn int_path_declines_inexact_operands_and_stays_correct() {
    let mut rng = Rng::new(0xDEC1);
    let int = KernelSel::resolve(KernelKind::Int);
    let (m, k, n) = (11, 48, 19);
    // continuous activations never sit on a grid: forced int must fall
    // back to f32 and still match the reference exactly
    let x = random_matrix(&mut rng, m, k, 0.3);
    let gw = grid_matrix(&mut rng, k, n, 0.1);
    assert!(reference_crossbar_int(&x, &gw, 0.25, 4.0, 8).is_none());
    let packed = PackedMatrix::pack_with(&gw.data, k, n, true);
    let reference = reference_crossbar_matmul(&x, &gw, 0.25, 4.0, 8);
    let mut got = vec![f32::NAN; m * n];
    let path =
        crossbar_matmul_packed_with(&x.data, m, k, &packed, 0.25, 4.0, 8, &mut got, 1, int);
    assert_ne!(path, KernelPath::Int, "continuous x must not engage int");
    assert_eq!(got, reference.data);
    // odd sub-K groups straddle the pmaddwd pairing: declined, still exact
    let gx = grid_matrix(&mut rng, m, k, 0.2);
    assert!(reference_crossbar_int(&gx, &gw, 0.25, 4.0, 7).is_none());
    let reference = reference_crossbar_matmul(&gx, &gw, 0.25, 4.0, 7);
    let mut got = vec![f32::NAN; m * n];
    let path =
        crossbar_matmul_packed_with(&gx.data, m, k, &packed, 0.25, 4.0, 7, &mut got, 1, int);
    assert_ne!(path, KernelPath::Int, "odd group must not engage int");
    assert_eq!(got, reference.data);
}

#[test]
fn simd_tail_sweep_covers_every_nr_mr_remainder() {
    // proptest-style exhaustive sweep of the tile tails: n % NR in 1..=7
    // and m % MR in 1..=3 (plus the exact-tile cases), forced simd vs
    // forced scalar
    let mut rng = Rng::new(0x7A11);
    let simd = KernelSel::resolve(KernelKind::Simd);
    let scalar = KernelSel::resolve(KernelKind::Scalar);
    for mrem in 0..4usize {
        for nrem in 0..8usize {
            let m = 8 + mrem; // 8 % MR == 0, so m % MR == mrem
            let n = 16 + nrem; // 16 % NR == 0, so n % NR == nrem
            let k = 1 + rng.below(64);
            let group = 1 + rng.below(24);
            let x = random_matrix(&mut rng, m, k, 0.25);
            let w = random_matrix(&mut rng, k, n, 0.1);
            let packed = PackedMatrix::pack(&w.data, k, n);
            let mut want = vec![f32::NAN; m * n];
            crossbar_matmul_packed_with(
                &x.data, m, k, &packed, 0.125, 2.0, group, &mut want, 1, scalar,
            );
            let mut got = vec![f32::NAN; m * n];
            crossbar_matmul_packed_with(
                &x.data, m, k, &packed, 0.125, 2.0, group, &mut got, 1, simd,
            );
            assert_eq!(got, want, "m={m} n={n} k={k} group={group} (tails {mrem}/{nrem})");
        }
    }
}
