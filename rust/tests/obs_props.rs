//! Cross-cutting observability properties: the span recorder produces
//! valid, structurally sound Chrome `trace_event` JSON; real study and
//! serve runs emit spans from every instrumented layer (study, serve,
//! batch, exec); the metric registry's histogram semantics match the
//! serving metrics they replaced, exactly; and disabled tracing stays
//! cheap enough for the kernel hot path.
//!
//! The trace gate is process-global, so every test that records or drains
//! serializes on one mutex and starts from a disabled, drained state.
//! Everything runs on the materialized synthetic artifact + the native
//! backend — no `make artifacts`, no xla.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use hybridac::coordinator::Metrics;
use hybridac::eval::Method;
use hybridac::exec::BackendKind;
use hybridac::obs::global;
use hybridac::obs::trace::{self, TraceEvent};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::scenario::Scenario;
use hybridac::serve::{drive_workload, FleetConfig, Router};
use hybridac::study::{Axis, Study, StudyRunner};
use hybridac::util::json::Json;

/// Serializes every trace-touching test and hands it a disabled, drained
/// recorder (poison is ignored: a panicked neighbor must not cascade).
static GATE: Mutex<()> = Mutex::new(());

fn trace_lock() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::disable();
    trace::drain();
    g
}

/// Materialize the synthetic artifact + dataset once per test process.
fn synthetic_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("hybridac-obs-{}", std::process::id()));
        Artifact::materialize_synthetic(&dir).expect("materialize synthetic artifact");
        dir
    })
    .clone()
}

/// Per thread, begin/end events must nest LIFO with matching names and
/// timestamps must be monotone — the two structural properties that make
/// a trace render as a sane flame graph.
fn check_structure(events: &[TraceEvent]) {
    let mut stacks: BTreeMap<u64, Vec<&str>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, u64> = BTreeMap::new();
    for e in events {
        let last = last_ts.entry(e.tid).or_insert(0);
        assert!(
            e.ts_us >= *last,
            "tid {}: time went backwards ({} after {})",
            e.tid,
            e.ts_us,
            last
        );
        *last = e.ts_us;
        let stack = stacks.entry(e.tid).or_default();
        match e.phase {
            'B' => stack.push(e.name.as_ref()),
            'E' => {
                let open = stack
                    .pop()
                    .unwrap_or_else(|| panic!("tid {}: end '{}' without a begin", e.tid, e.name));
                assert_eq!(open, e.name.as_ref(), "tid {}: mismatched begin/end", e.tid);
            }
            'i' => {}
            other => panic!("unknown phase '{other}'"),
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "tid {tid}: unclosed spans {stack:?}");
    }
}

#[test]
fn trace_json_is_valid_and_structurally_sound() {
    let _g = trace_lock();
    trace::enable();
    {
        let _outer = trace::span("outer", "test");
        {
            let _inner = trace::span_dyn("test", || format!("inner-{}", 1));
        }
        trace::instant("mark", "test");
    }
    std::thread::spawn(|| {
        let _w = trace::span("worker", "test");
    })
    .join()
    .unwrap();
    trace::disable();

    let events = trace::drain();
    assert_eq!(events.len(), 7, "3 span pairs + 1 instant");
    check_structure(&events);

    // the rendered document parses back and carries every required
    // trace_event field (what Perfetto / about:tracing validate on load)
    let text = trace::chrome_trace_json(&events).to_string();
    let back = Json::parse(&text).expect("trace JSON must parse");
    let arr = back.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert_eq!(arr.len(), events.len());
    for e in arr {
        assert!(e.get("name").and_then(Json::as_str).is_some(), "{text}");
        assert!(e.get("cat").and_then(Json::as_str).is_some(), "{text}");
        assert!(e.get("ts").and_then(Json::as_f64).is_some(), "{text}");
        assert!(e.get("pid").and_then(Json::as_f64).is_some(), "{text}");
        assert!(e.get("tid").and_then(Json::as_f64).is_some(), "{text}");
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        assert!(["B", "E", "i"].contains(&ph), "unknown phase '{ph}'");
        if ph == "i" {
            assert_eq!(e.get("s").and_then(Json::as_str), Some("t"), "instants need a scope");
        }
    }
}

#[test]
fn study_run_emits_study_and_exec_spans_and_timing() {
    let _g = trace_lock();
    let runs_before = global().snapshot().counter("exec_native_runs_total");
    trace::enable();
    let study = Study {
        name: "obs-e2e".to_string(),
        base: Scenario::paper_default("obs-e2e", "synthetic", Method::Hybrid { frac: 0.16 })
            .with_backend(BackendKind::Native)
            .with_eval(16, 1),
        axes: vec![Axis::Frac(vec![0.0, 0.16])],
    };
    let report = StudyRunner::new(synthetic_dir()).with_workers(2).run(&study).unwrap();
    trace::disable();

    let events = trace::drain();
    check_structure(&events);
    let cats: BTreeSet<&str> = events.iter().map(|e| e.cat).collect();
    assert!(cats.contains("study"), "study spans missing (got {cats:?})");
    assert!(cats.contains("exec"), "exec spans missing (got {cats:?})");
    let names: Vec<&str> = events.iter().map(|e| e.name.as_ref()).collect();
    assert!(names.iter().any(|n| n.starts_with("study ")), "whole-study span");
    assert!(names.iter().any(|n| n.starts_with("point ")), "per-point spans");
    assert!(names.contains(&"native/run"), "backend run span");
    for stage in ["im2col", "act_quant", "xbar/wa1", "digital/wd", "fp16/merge"] {
        assert!(names.contains(&stage), "missing per-stage kernel span '{stage}'");
    }

    // the global registry counted the native executions
    let runs_after = global().snapshot().counter("exec_native_runs_total");
    assert!(runs_after > runs_before, "exec_native_runs_total must advance");

    // timing side channel: one record per point in grid order, usable
    // worker ids — and none of it leaks into the byte-pinned main report
    assert_eq!(report.timing.len(), report.points.len());
    for (t, p) in report.timing.iter().zip(&report.points) {
        assert_eq!(t.index, p.index);
        assert_eq!(t.id, p.id);
        assert!(t.secs >= 0.0);
        assert!(t.worker < report.workers, "worker id {} of {}", t.worker, report.workers);
    }
    let tj = Json::parse(&report.timing_json().to_string()).unwrap();
    assert_eq!(tj.get("workers").and_then(Json::as_f64), Some(report.workers as f64));
    assert_eq!(tj.get("points").and_then(Json::as_arr).unwrap().len(), report.points.len());
    assert!(
        !report.to_json().to_string().contains("secs"),
        "wall-clock must stay out of the main report"
    );
    assert_eq!(report.timing_file_name(), "BENCH_study_obs-e2e.timing.json");
}

#[test]
fn serve_fleet_emits_serve_and_batch_spans() {
    let _g = trace_lock();
    trace::enable();
    let dir = synthetic_dir();
    let data = Arc::new(DatasetBlob::load(&dir, "synthetic").unwrap());
    let sc = Scenario::paper_default("obs-serve", "synthetic", Method::Hybrid { frac: 0.16 })
        .with_backend(BackendKind::Native)
        .with_eval(32, 2);
    let mut fleet = FleetConfig::new(2);
    fleet.max_wait = Duration::from_millis(2);
    let router = Arc::new(Router::start_scenario(dir, sc, fleet).unwrap());
    let (_hits, total) = drive_workload(&router, &data, 32, 2).unwrap();
    assert_eq!(total, 32);
    router.probe(&data, 8);
    let fm = router.fleet_metrics();
    Arc::try_unwrap(router).ok().unwrap().shutdown().unwrap();
    trace::disable();

    let events = trace::drain();
    check_structure(&events);
    let names: Vec<&str> = events.iter().map(|e| e.name.as_ref()).collect();
    assert!(names.iter().any(|n| n.starts_with("replica/spawn")), "spawn spans: {names:?}");
    assert!(names.contains(&"probe/sweep"), "probe sweep span");
    assert!(names.contains(&"batch/collect"), "batch collect span");
    assert!(names.contains(&"batch/execute"), "batch execute span");
    assert!(names.contains(&"batch/enqueue"), "enqueue instants");

    // queue-depth, shed-by-kind, and probe-failure series render in the
    // fleet's Prometheus snapshot even when their values are zero
    let text = fm.to_registry_snapshot().prometheus();
    assert!(text.contains("serve_queue_depth"), "{text}");
    assert!(text.contains("serve_shed_queue_full_total"), "{text}");
    assert!(text.contains("serve_shed_bad_request_total"), "{text}");
    assert!(text.contains("serve_probe_failures"), "{text}");
    assert!(text.contains("serve_latency_us_bucket"), "{text}");
}

#[test]
fn registry_histogram_semantics_match_the_old_metrics() {
    // the registry-backed Metrics must report the exact values the old
    // hand-rolled histogram did: percentiles at the upper bucket edge, an
    // overflow bucket reporting twice the last edge (500 ms), and mean =
    // latency sum over requests
    let m = Metrics::new();
    m.record_request();
    m.record_latency(Duration::from_micros(60)); // (50, 100] bucket
    assert_eq!(m.latency_percentile_ms(0.5), 0.1);
    m.record_request();
    m.record_latency(Duration::from_millis(400)); // past the 250 ms edge
    assert_eq!(m.latency_percentile_ms(0.99), 500.0);
    let want_mean = (60.0 + 400_000.0) / 2.0 / 1000.0;
    assert!((m.mean_latency_ms() - want_mean).abs() < 1e-9);
}

#[test]
fn disabled_tracing_overhead_stays_negligible() {
    let _g = trace_lock(); // tracing is off for the whole measurement
    let n = 1_000_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        let _s = trace::span("hot", "bench");
        std::hint::black_box(i);
    }
    let dt = t0.elapsed();
    assert!(trace::drain().is_empty(), "disabled tracing recorded events");
    // the disabled path is one relaxed load + a branch; 400 ns/call leaves
    // two orders of magnitude of headroom even for debug builds on a
    // loaded CI machine
    assert!(dt < Duration::from_millis(400), "1M disabled spans took {dt:?}");
}
