//! Study-layer properties: grid expansion size/ordering/ID stability,
//! Study JSON round-trip + strict parse errors, and an end-to-end
//! native-backend run of a 2-axis synthetic study pinning 4-worker
//! results byte-identical to sequential.
//!
//! Everything here runs with no built artifacts and no xla (synthetic
//! artifact + native backend), in both the default and the
//! `--no-default-features` builds.

use std::path::PathBuf;
use std::sync::OnceLock;

use hybridac::eval::Method;
use hybridac::exec::BackendKind;
use hybridac::noise::CellModel;
use hybridac::quantize::QuantConfig;
use hybridac::runtime::Artifact;
use hybridac::scenario::{Scenario, SplitSpec};
use hybridac::study::{
    Axis, MethodKey, SearchParams, SearchValue, Study, StudyRunner, VariantPatch,
};

/// Materialize the synthetic artifact + dataset once per test process.
fn synthetic_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("hybridac-study-{}", std::process::id()));
        Artifact::materialize_synthetic(&dir).expect("materialize synthetic artifact");
        dir
    })
    .clone()
}

fn base_native() -> Scenario {
    Scenario::paper_default("study-e2e", "synthetic", Method::Hybrid { frac: 0.16 })
        .with_backend(BackendKind::Native)
        .with_eval(32, 2)
}

#[test]
fn grid_expansion_size_ordering_and_ids() {
    let study = Study {
        name: "grid".to_string(),
        base: base_native(),
        axes: vec![
            Axis::Method(vec![MethodKey::Hybrid, MethodKey::Iws]),
            Axis::Frac(vec![0.0, 0.08, 0.16]),
        ],
    };
    let points = study.points().unwrap();
    assert_eq!(points.len(), 6, "2 x 3 grid");
    // row-major, first axis outermost; IDs are spec-derived and stable
    let ids: Vec<&str> = points.iter().map(|p| p.id.as_str()).collect();
    assert_eq!(
        ids,
        vec![
            "method=hybrid,frac=0",
            "method=hybrid,frac=0.08",
            "method=hybrid,frac=0.16",
            "method=iws,frac=0",
            "method=iws,frac=0.08",
            "method=iws,frac=0.16",
        ]
    );
    for (i, p) in points.iter().enumerate() {
        assert_eq!(p.index, i, "index matches expansion order");
    }
    assert_eq!(points[1].scenario.split, SplitSpec::Channels { frac: 0.08 });
    assert_eq!(points[4].scenario.split, SplitSpec::Iws { frac: 0.08 });
    assert_eq!(points[0].scenario.name, "grid[method=hybrid,frac=0]");

    // expansion is a pure function of the spec
    let again = study.points().unwrap();
    for (a, b) in points.iter().zip(&again) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.scenario, b.scenario);
    }
}

#[test]
fn study_json_round_trips_every_axis_kind() {
    let study = Study {
        name: "rt".to_string(),
        base: base_native(),
        axes: vec![
            Axis::Method(vec![MethodKey::Hybrid, MethodKey::Unprotected, MethodKey::Clean]),
            Axis::Frac(vec![0.0, 0.16]),
            Axis::AdcBits(vec![Some(8), None]),
            Axis::Sigma(vec![0.25, 0.5]),
            Axis::Group(vec![64, 128]),
            Axis::Model(vec!["synthetic".to_string()]),
            Axis::Seed(vec![7, 9]),
            Axis::Variant(vec![
                VariantPatch {
                    name: "di4".to_string(),
                    cell: Some(CellModel::differential(0.5)),
                    adc_bits: Some(Some(4)),
                    ..VariantPatch::default()
                },
                VariantPatch {
                    name: "hq".to_string(),
                    method: Some(MethodKey::Iws),
                    frac: Some(0.1),
                    quant: Some(Some(QuantConfig::hybrid())),
                    ..VariantPatch::default()
                },
                VariantPatch {
                    name: "bare".to_string(),
                    quant: Some(None),
                    adc_bits: Some(None),
                    sigma: Some(0.3),
                    group: Some(32),
                    seed: Some(11),
                    ..VariantPatch::default()
                },
            ]),
        ],
    };
    let text = study.to_json().to_string();
    let back = Study::parse(&text).unwrap();
    assert_eq!(study, back, "{text}");

    // the search axis round-trips with its parameters
    let search = Study {
        name: "rt-search".to_string(),
        base: base_native(),
        axes: vec![Axis::Search {
            values: vec![SearchValue::None, SearchValue::Hybrid, SearchValue::Iws],
            params: SearchParams { target_drop: 0.05, max_frac: 0.25, step: 0.05 },
        }],
    };
    let text = search.to_json().to_string();
    assert_eq!(Study::parse(&text).unwrap(), search, "{text}");
}

#[test]
fn bad_study_specs_fail_loudly() {
    let base = r#""base": {"model": "synthetic", "split": {"kind": "channels", "frac": 0.16},
                  "backend": "native"}"#;
    // unknown axis key is a strict parse error (mirrors Scenario.backend)
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "fraction", "values": [0.1]}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "unknown axis key");
    // unknown key inside an axis object
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "frac", "values": [0.1], "step": 2}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "stray key in a non-search axis");
    // unknown top-level study key
    let bad = format!(r#"{{"name": "x", {base}, "axis": []}}"#);
    assert!(Study::parse(&bad).is_err(), "misspelled 'axes'");
    // mistyped values must never silently coerce
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "frac", "values": ["0.1"]}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "string frac");
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "seed", "values": [1.5]}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "fractional seed");
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "adc_bits", "values": [64]}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "64-bit ADC");
    // duplicate axes are ambiguous
    let bad = format!(
        r#"{{"name": "x", {base}, "axes": [{{"key": "frac", "values": [0.1]}},
            {{"key": "frac", "values": [0.2]}}]}}"#
    );
    assert!(Study::parse(&bad).is_err(), "duplicate axis");
    // the search axis owns the split: no method/frac alongside it
    let bad = format!(
        r#"{{"name": "x", {base}, "axes": [{{"key": "search", "values": ["hybrid"]}},
            {{"key": "frac", "values": [0.1]}}]}}"#
    );
    assert!(Study::parse(&bad).is_err(), "search + frac");
    // an unknown search value
    let bad = format!(r#"{{"name": "x", {base}, "axes": [{{"key": "search", "values": ["all"]}}]}}"#);
    assert!(Study::parse(&bad).is_err(), "unknown search value");

    // a frac axis over an all-analog base fails at expansion, loudly
    let study = Study {
        name: "x".to_string(),
        base: Scenario::paper_default("x", "synthetic", Method::NoProtection)
            .with_backend(BackendKind::Native),
        axes: vec![Axis::Frac(vec![0.1])],
    };
    assert!(study.points().is_err(), "frac without a split to land on");
}

#[test]
fn builtin_studies_expand_and_round_trip() {
    for (key, _) in Study::builtin_names() {
        let study = Study::named(key, "resnet18m_c10s").expect(key);
        assert_eq!(&study.name, key);
        let points = study.points().unwrap_or_else(|e| panic!("{key}: {e}"));
        assert!(!points.is_empty(), "{key} expanded to an empty grid");
        let text = study.to_json().to_string();
        let back = Study::parse(&text).unwrap_or_else(|e| panic!("{key}: {e}"));
        assert_eq!(study, back, "builtin '{key}' does not round-trip");
    }
    assert!(Study::named("nope", "m").is_none());
    assert!(Study::named("table1-in50s", "m").is_none(), "Table 1 is CIFAR-only");
}

#[test]
fn parallel_study_matches_sequential_byte_for_byte() {
    let dir = synthetic_dir();
    let study = Study {
        name: "par-vs-seq".to_string(),
        base: base_native(),
        axes: vec![
            Axis::Method(vec![MethodKey::Hybrid, MethodKey::Iws]),
            Axis::Frac(vec![0.0, 0.16]),
        ],
    };
    let seq = StudyRunner::new(&dir).with_workers(1).run(&study).unwrap();
    let par = StudyRunner::new(&dir).with_workers(4).run(&study).unwrap();
    assert_eq!(seq.points.len(), 4);
    assert_eq!(seq.workers, 1);
    assert_eq!(par.workers, 4);
    for p in &seq.points {
        assert!((0.0..=1.0).contains(&p.mean), "point '{}' accuracy {}", p.id, p.mean);
    }
    let a = seq.to_json().to_string();
    let b = par.to_json().to_string();
    assert_eq!(a, b, "a 4-worker study must serialize byte-identical to 1-worker");
}

#[test]
fn search_axis_finds_a_crossing_end_to_end() {
    let dir = synthetic_dir();
    let study = Study {
        name: "search-e2e".to_string(),
        base: Scenario::paper_default("search-e2e", "synthetic", Method::NoProtection)
            .with_backend(BackendKind::Native)
            .with_eval(24, 1),
        axes: vec![Axis::Search {
            values: vec![SearchValue::None, SearchValue::Hybrid],
            params: SearchParams { target_drop: 0.9, max_frac: 0.3, step: 0.1 },
        }],
    };
    let rep = StudyRunner::new(&dir).with_workers(2).run(&study).unwrap();
    assert_eq!(rep.points.len(), 2);
    assert!(!rep.points[0].searched, "the 'none' value evaluates the base as-is");
    let crossing = &rep.points[1];
    assert!(crossing.searched);
    // a near-zero target is reached immediately at the pinned-weight floor
    assert!(crossing.frac <= 0.3 + 1e-9, "crossing {} past max_frac", crossing.frac);
    assert!(crossing.mean >= crossing.clean - 0.9, "crossing missed the target");
    assert!(rep.table().contains("search"), "table renders the search axis column");
    assert_eq!(rep.json_file_name(), "BENCH_study_search-e2e.json");
}

#[test]
fn missing_artifacts_skip_loudly_not_silently() {
    let dir = synthetic_dir();
    let study = Study {
        name: "skip".to_string(),
        base: base_native(),
        axes: vec![Axis::Model(vec!["synthetic".to_string(), "not_built_xyz".to_string()])],
    };
    let rep = StudyRunner::new(&dir).with_workers(1).run(&study).unwrap();
    assert_eq!(rep.points.len(), 1, "only the built model runs");
    assert_eq!(rep.skipped_models, vec!["not_built_xyz".to_string()]);
    assert_eq!(rep.points[0].model, "synthetic");
}
