//! Integration tests over real artifacts + the PJRT runtime.
//!
//! These need `make artifacts` to have produced at least vggmini_c10s /
//! resnet18m_c10s; they are skipped (with a notice) otherwise so `cargo
//! test` stays green on a fresh checkout.

use hybridac::eval::{prepare, Evaluator, ExperimentConfig, Method};
use hybridac::exec::{BackendKind, ModelExecutor};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::selection::{IwsMasks, Partition};
use hybridac::util::prop::{check, gen};
use hybridac::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = hybridac::artifacts_dir();
    if dir.join("vggmini_c10s.meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        None
    }
}

#[test]
fn artifact_loads_and_is_consistent() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    assert_eq!(art.family, "vggmini");
    assert_eq!(art.dataset, "c10s");
    assert_eq!(art.layers.len(), art.weights.len());
    assert_eq!(art.layers.len(), art.act_ranges.len());
    let total: usize = art.layers.iter().map(|l| l.n_weights()).sum();
    assert_eq!(total, art.total_weights);
    // ranking covers every non-pinned channel exactly once
    let expect: usize = art
        .layers
        .iter()
        .filter(|l| !l.always_digital)
        .map(|l| l.cin)
        .sum();
    assert_eq!(art.ranking.len(), expect);
    // scores descending
    assert!(art.ranking.windows(2).all(|w| w[0].score >= w[1].score));
}

#[test]
fn dataset_blob_loads() {
    let Some(dir) = artifacts() else { return };
    let data = DatasetBlob::load(&dir, "c10s").unwrap();
    assert_eq!(data.n, 1000);
    assert_eq!(data.shape, vec![16, 16, 3]);
    assert!(data.labels.iter().all(|&l| (0..10).contains(&l)));
    let (batch, labels) = data.batch(0, 250);
    assert_eq!(batch.shape, vec![250, 16, 16, 3]);
    assert_eq!(labels.len(), 250);
}

#[test]
fn partition_is_a_partition() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    // property: for any fraction, every weight is in exactly one of
    // (analog copy, digital copy) and split preserves values
    check(
        "partition-disjoint-complete",
        12,
        gen::f64_in(0.0, 0.4),
        |&frac| {
            let p = Partition::for_fraction(&art, frac);
            for (li, w) in art.weights.iter().enumerate() {
                let (wa, wd) = p.split_layer(&art, li, w);
                for i in 0..w.data.len() {
                    let (a, d, orig) = (wa.data[i], wd.data[i], w.data[i]);
                    if orig != 0.0 && !((a == orig && d == 0.0) ^ (d == orig && a == 0.0)) {
                        return Err(format!(
                            "layer {li} weight {i}: orig {orig} split to ({a}, {d})"
                        ));
                    }
                }
            }
            if p.protected_frac < frac - 1e-9 && p.n_selected < art.ranking.len() {
                return Err(format!(
                    "protected_frac {} below requested {frac}",
                    p.protected_frac
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn partition_monotone_in_fraction() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    let mut prev = 0;
    for f in [0.0, 0.05, 0.1, 0.2, 0.3] {
        let p = Partition::for_fraction(&art, f);
        let n: usize = p.digital_channels.iter().map(|d| d.len()).sum();
        assert!(n >= prev, "digital channels shrank at frac {f}");
        prev = n;
    }
}

#[test]
fn iws_masks_hit_requested_fraction() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    for f in [0.05, 0.1, 0.2] {
        let m = IwsMasks::for_fraction(&art, f);
        assert!(
            (m.protected_frac - f).abs() < 0.05,
            "requested {f}, got {}",
            m.protected_frac
        );
    }
}

#[test]
fn prepared_model_respects_contract() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    let cfg = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
    let mut rng = Rng::new(5);
    let model = prepare(&art, &cfg, &mut rng);
    assert_eq!(model.layers.len(), art.layers.len());
    for (li, l) in model.layers.iter().enumerate() {
        let rows = art.layers[li].rows();
        assert_eq!(l.wa1.shape, vec![rows, art.layers[li].cout]);
        assert!(l.lsb > 0.0, "ADC enabled by default");
        assert!(l.clip > 0.0);
        // offset cells: wa2 is all zeros
        assert!(l.wa2.data.iter().all(|&v| v == 0.0));
    }
    // differential cells populate both polarities, non-negative
    let mut cfg_di = cfg.clone();
    cfg_di.cell = hybridac::noise::CellModel::differential(0.5);
    let model_di = prepare(&art, &cfg_di, &mut rng);
    let some_neg = model_di.layers.iter().any(|l| l.wa2.data.iter().any(|&v| v > 0.0));
    assert!(some_neg, "differential split must populate the negative array");
    for l in &model_di.layers {
        assert!(l.wa1.data.iter().all(|&v| v >= 0.0));
        assert!(l.wa2.data.iter().all(|&v| v >= 0.0));
    }
}

#[test]
fn clean_config_reproduces_export_accuracy() {
    let Some(dir) = artifacts() else { return };
    let ev = Evaluator::new(&dir, "vggmini_c10s").unwrap();
    let clean = ev.clean_accuracy(500).unwrap();
    // exported test_acc was measured on the full 1000 in float; the staged
    // 500-sample subset through the quantized-activation graph must agree
    // within a few points
    assert!(
        (clean - ev.art.clean_test_acc).abs() < 0.05,
        "clean {} vs exported {}",
        clean,
        ev.art.clean_test_acc
    );
}

#[test]
fn protection_recovers_accuracy() {
    let Some(dir) = artifacts() else { return };
    let ev = Evaluator::new(&dir, "vggmini_c10s").unwrap();
    let mut base = ExperimentConfig::paper_default(Method::NoProtection);
    base.n_eval = 250;
    base.repeats = 2;
    let unprot = ev.accuracy(&base).unwrap();
    let mut hyb = base.clone();
    hyb.method = Method::Hybrid { frac: 0.2 };
    let prot = ev.accuracy(&hyb).unwrap();
    assert!(
        prot.mean > unprot.mean + 0.2,
        "protection must recover >20 points: {} vs {}",
        prot.mean,
        unprot.mean
    );
}

#[test]
fn executor_is_deterministic_given_seed() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    let data = DatasetBlob::load(&dir, "c10s").unwrap();
    let cfg = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
    // the build's default backend: pjrt when compiled in, native otherwise
    let backend = BackendKind::default().create().unwrap();
    let exec = ModelExecutor::new(backend.as_ref(), &art, &data, 250, cfg.group).unwrap();
    let mut r1 = Rng::new(99);
    let m1 = prepare(&art, &cfg, &mut r1);
    let a1 = exec.accuracy(&m1).unwrap();
    let mut r2 = Rng::new(99);
    let m2 = prepare(&art, &cfg, &mut r2);
    let a2 = exec.accuracy(&m2).unwrap();
    assert_eq!(a1, a2, "same seed must give identical accuracy");
}
