//! Integration tests for the replicated serving fleet (router + replicas +
//! admission + health recycling) over real artifacts + the PJRT runtime.
//!
//! Like `artifact_integration.rs`, these need `make artifacts` to have
//! produced vggmini_c10s; they are skipped (with a notice) otherwise so
//! `cargo test` stays green on a fresh checkout.

use std::sync::Arc;
use std::time::Duration;

use hybridac::eval::{prepare, ExperimentConfig, Method};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::serve::{drive_workload, FleetConfig, HealthPolicy, HealthStatus, Router, ServeError};
use hybridac::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = hybridac::artifacts_dir();
    if dir.join("vggmini_c10s.meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        None
    }
}

fn hybrid_cfg() -> ExperimentConfig {
    ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 })
}

/// Replicas seeded differently must hold *independent* variation draws
/// (different prepared weights), yet every draw must stay within the
/// protection method's accuracy tolerance — the paper's robustness claim
/// as a fleet property.
#[test]
fn fleet_replicas_draw_independent_variation() {
    let Some(dir) = artifacts() else { return };
    let data = {
        let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
        DatasetBlob::load(&dir, &art.dataset).unwrap()
    };
    let mut fleet = FleetConfig::new(2);
    fleet.max_wait = Duration::from_millis(5);
    let router = Router::start(dir, "vggmini_c10s".into(), hybrid_cfg(), fleet).unwrap();

    let fm = router.fleet_metrics();
    assert_eq!(fm.replicas.len(), 2);
    assert_ne!(
        fm.replicas[0].fingerprint, fm.replicas[1].fingerprint,
        "differently-seeded replicas must hold different noisy instances"
    );
    assert_ne!(fm.replicas[0].seed, fm.replicas[1].seed);

    // every replica's observed accuracy stays within tolerance: HybridAC@16%
    // recovers to within a few points of clean (~0.85 on the scaled models),
    // so well above 0.5 for any healthy draw
    let accs = router.probe(&data, 200);
    for (i, acc) in accs.iter().enumerate() {
        assert!(
            *acc > 0.5,
            "replica {i} accuracy {acc} below tolerance despite protection"
        );
    }
    let fm = router.fleet_metrics();
    for r in &fm.replicas {
        assert_eq!(r.status, HealthStatus::Healthy, "replica {} unhealthy", r.id);
        assert!(r.alive, "replica {} worker died", r.id);
        assert!(r.probes >= 200, "probe outcomes recorded in health, not serving metrics");
        assert_eq!(r.metrics.requests, 0, "probes must not count as served traffic");
    }
    assert_eq!(fm.total.requests, fm.replicas.iter().map(|r| r.metrics.requests).sum::<u64>());
    router.shutdown().unwrap();
}

/// Same (replica, generation) seed ⇒ the exact same draw as a direct
/// `prepare` call is deterministic; the fleet adds no hidden randomness.
#[test]
fn same_seed_same_draw_different_seed_different_draw() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    let cfg = hybrid_cfg();
    let mut cfg_a = cfg.clone();
    cfg_a.seed = 1234;
    let mut cfg_b = cfg.clone();
    cfg_b.seed = 5678;
    let m_a1 = prepare(&art, &cfg_a, &mut Rng::new(cfg_a.seed));
    let m_a2 = prepare(&art, &cfg_a, &mut Rng::new(cfg_a.seed));
    let m_b = prepare(&art, &cfg_b, &mut Rng::new(cfg_b.seed));
    assert_eq!(
        m_a1.layers[0].wa1.data, m_a2.layers[0].wa1.data,
        "same seed must reproduce the draw"
    );
    assert_ne!(
        m_a1.layers[0].wa1.data, m_b.layers[0].wa1.data,
        "different seeds must give different draws"
    );
}

/// Admission: with a tiny queue and the single worker busy inside a batch
/// execution, a burst must be shed with the typed error — not silently
/// queued without bound.
#[test]
fn router_sheds_on_full_queues() {
    let Some(dir) = artifacts() else { return };
    let data = {
        let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
        DatasetBlob::load(&dir, &art.dataset).unwrap()
    };
    let per = data.image_elems();
    let image = || data.images[..per].to_vec();

    let mut fleet = FleetConfig::new(1);
    fleet.queue_depth = 2;
    // zero window: the worker grabs the first request immediately and goes
    // busy executing a (mostly padded) batch, leaving the queue to fill
    fleet.max_wait = Duration::ZERO;
    let router = Router::start(dir, "vggmini_c10s".into(), hybrid_cfg(), fleet).unwrap();

    let first = router.submit(image()).expect("first request admitted");
    std::thread::sleep(Duration::from_millis(30)); // let the worker start the batch
    let mut shed = 0;
    let mut admitted = Vec::new();
    for _ in 0..50 {
        match router.submit(image()) {
            Ok(rx) => admitted.push(rx),
            Err(e) => {
                assert!(
                    matches!(e, ServeError::QueueFull { replicas: 1, depth: 2 }),
                    "unexpected error {e:?}"
                );
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "a 50-request burst into a depth-2 queue must shed");
    assert!(first.recv().is_ok(), "admitted request still served");
    for rx in admitted {
        assert!(rx.recv().is_ok(), "queued requests drain after the burst");
    }
    assert_eq!(router.fleet_metrics().shed, shed as u64);

    // admission also rejects wrong-size payloads with a typed error
    // (never letting them near a worker), and that is not a shed
    assert!(matches!(
        router.submit(vec![0.0; per + 1]),
        Err(ServeError::BadRequest { want, .. }) if want == per
    ));
    assert_eq!(router.fleet_metrics().shed, shed as u64);
    router.shutdown().unwrap();
}

/// Health recycling: an (artificially) unreachable accuracy floor flags
/// every replica Degraded; recycling swaps in a new generation with a fresh
/// variation draw that keeps serving.
#[test]
fn degraded_replicas_are_recycled_with_fresh_draws() {
    let Some(dir) = artifacts() else { return };
    let data = {
        let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
        DatasetBlob::load(&dir, &art.dataset).unwrap()
    };
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(5);
    fleet.health = HealthPolicy { accuracy_floor: 1.01, min_probes: 8 };
    let router = Router::start(dir, "vggmini_c10s".into(), hybrid_cfg(), fleet).unwrap();

    let before = router.fleet_metrics().replicas[0].clone();
    router.probe(&data, 16);
    assert_eq!(
        router.fleet_metrics().replicas[0].status,
        HealthStatus::Degraded,
        "an impossible floor must flag the replica"
    );

    let recycled = router.recycle_degraded().unwrap();
    assert_eq!(recycled, vec![0]);
    let after = router.fleet_metrics().replicas[0].clone();
    assert_eq!(after.generation, before.generation + 1);
    assert_ne!(after.seed, before.seed, "recycle must re-seed");
    assert_ne!(after.fingerprint, before.fingerprint, "recycle must redraw variation");
    assert_eq!(after.probe_accuracy, None, "fresh generation starts a clean record");
    assert_eq!(router.fleet_metrics().recycled, 1);

    // the recycled replica serves traffic
    let per = data.image_elems();
    let rx = router.submit(data.images[..per].to_vec()).unwrap();
    assert!(rx.recv().is_ok());
    router.shutdown().unwrap();
}

/// The fleet keeps the end-to-end contract: predictions routed back to the
/// right callers under concurrent multi-client load.
#[test]
fn fleet_serves_concurrent_clients_correctly() {
    let Some(dir) = artifacts() else { return };
    let data = Arc::new({
        let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
        DatasetBlob::load(&dir, &art.dataset).unwrap()
    });
    let mut fleet = FleetConfig::new(2);
    fleet.max_wait = Duration::from_millis(5);
    let router = Arc::new(
        Router::start(dir, "vggmini_c10s".into(), hybrid_cfg(), fleet).unwrap(),
    );

    let n_requests = 300;
    let (hits, total) = drive_workload(&router, &data, n_requests, 4).unwrap();
    assert_eq!(total, n_requests, "every admitted request must be answered");
    let acc = hits as f64 / total as f64;
    assert!(acc > 0.5, "fleet accuracy {acc} below protection tolerance");

    let fm = router.fleet_metrics();
    assert_eq!(fm.total.requests, n_requests as u64);
    assert!(
        fm.replicas.iter().all(|r| r.metrics.requests > 0),
        "round-robin must spread load over both replicas: {:?}",
        fm.replicas.iter().map(|r| r.metrics.requests).collect::<Vec<_>>()
    );
    Arc::try_unwrap(router).ok().unwrap().shutdown().unwrap();
}
