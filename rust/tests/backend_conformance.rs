//! Backend conformance: one shared suite of execution-contract checks, run
//! unconditionally against the pure-rust `NativeBackend` (on the
//! materialized synthetic artifact — no `make artifacts`, no xla) and,
//! behind the usual artifact gate, against `PjrtBackend`.
//!
//! These are also the acceptance probes for the backend abstraction:
//! scenario evaluation, the batch server, and a whole replicated serve
//! fleet run end-to-end on the native backend — a code path that never
//! constructs an xla/PJRT engine (in a `--no-default-features` build that
//! is type-level: the pjrt module does not exist) — and an N-replica
//! native fleet compiles each graph variant exactly once through the
//! fleet-shared `CompiledGraphCache`.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use hybridac::coordinator::BatchServer;
use hybridac::eval::{Evaluator, Method};
use hybridac::exec::{BackendKind, ExecBackend, ModelExecutor, ModelInstance};
use hybridac::runtime::{Artifact, DatasetBlob, PreparedModel};
use hybridac::scenario::Scenario;
use hybridac::serve::{drive_workload, FleetConfig, HealthPolicy, HealthStatus, Router};
use hybridac::util::rng::Rng;

/// Materialize the synthetic artifact + dataset once per test process
/// (`OnceLock` serializes the racing test threads).
fn synthetic_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("hybridac-conformance-{}", std::process::id()));
        Artifact::materialize_synthetic(&dir).expect("materialize synthetic artifact");
        dir
    })
    .clone()
}

fn hybrid_scenario(model: &str) -> Scenario {
    Scenario::paper_default("conformance", model, Method::Hybrid { frac: 0.16 })
        .with_backend(BackendKind::Native)
        .with_eval(32, 2)
}

fn prepared(art: &Artifact, sc: &Scenario) -> PreparedModel {
    let mut rng = Rng::new(sc.seed);
    sc.pipeline().prepare(art, &mut rng)
}

/// Compile + upload + run one staged batch; the shared primitive of the
/// suite, exercised identically against either backend.
fn run_one_batch(
    backend: &dyn ExecBackend,
    art: &Artifact,
    data: &DatasetBlob,
    model: &PreparedModel,
    offset: bool,
) -> Vec<f32> {
    let compiled = backend.compile(art, art.group, offset).unwrap();
    let instance = ModelInstance::upload(backend, model, compiled.offset_variant).unwrap();
    let (x, _labels) = data.batch(0, art.batch);
    let xbuf = backend.upload(&x).unwrap();
    instance.run(backend, &compiled.exe, &xbuf).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "logit counts differ");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

// ---------------------------------------------------------------------------
// native backend: always runs, no artifacts, no xla

#[test]
fn native_logits_identical_across_backend_instances() {
    let dir = synthetic_dir();
    let art = Artifact::load(&dir, "synthetic").unwrap();
    let data = DatasetBlob::load(&dir, "synthetic").unwrap();
    let sc = hybrid_scenario("synthetic");
    let model = prepared(&art, &sc);

    let a = BackendKind::Native.create().unwrap();
    let b = BackendKind::Native.create().unwrap();
    assert_eq!(a.kind(), BackendKind::Native);
    let la = run_one_batch(a.as_ref(), &art, &data, &model, false);
    let lb = run_one_batch(b.as_ref(), &art, &data, &model, false);
    assert_eq!(la.len(), art.batch * art.num_classes);
    assert!(la.iter().all(|v| v.is_finite()), "logits must be finite");
    let diff = max_abs_diff(&la, &lb);
    assert!(diff <= 1e-4, "two backend instances diverged by {diff}");
    // each instance compiled the variant once
    assert_eq!(a.compiled_graphs(), 1);
    // re-running on the same instance hits the cache
    let _ = run_one_batch(a.as_ref(), &art, &data, &model, false);
    assert_eq!(a.compiled_graphs(), 1, "second run must reuse the compiled graph");
}

#[test]
fn native_offset_variant_matches_full_graph() {
    let dir = synthetic_dir();
    let art = Artifact::load(&dir, "synthetic").unwrap();
    let data = DatasetBlob::load(&dir, "synthetic").unwrap();
    // offset cells: wa2 is all zeros, so skipping it must not change math
    let sc = hybrid_scenario("synthetic");
    let model = prepared(&art, &sc);

    let backend = BackendKind::Native.create().unwrap();
    let full = run_one_batch(backend.as_ref(), &art, &data, &model, false);
    let fast = run_one_batch(backend.as_ref(), &art, &data, &model, true);
    let diff = max_abs_diff(&full, &fast);
    assert!(diff <= 1e-4, "offset fast path diverged by {diff}");
    assert_eq!(backend.compiled_graphs(), 2, "full + offset variants compile separately");
}

#[test]
fn native_evaluator_runs_scenarios_end_to_end() {
    let dir = synthetic_dir();
    let sc = hybrid_scenario("synthetic");
    let ev = Evaluator::for_scenario(&dir, &sc).unwrap();
    assert_eq!(ev.backend_kind(), BackendKind::Native);
    let acc = ev.run_scenario(&sc).unwrap();
    assert_eq!(acc.repeats, 2);
    assert!((0.0..=1.0).contains(&acc.mean), "accuracy {} out of range", acc.mean);

    // deterministic: the same scenario scores identically on a fresh run
    let again = ev.run_scenario(&sc).unwrap();
    assert_eq!(acc.mean, again.mean, "same seed, same accuracy");

    // the clean (perturbation-free) scenario runs a single repeat
    let clean = Scenario::paper_default("clean", "synthetic", Method::Clean)
        .with_backend(BackendKind::Native)
        .with_eval(32, 3);
    let clean_acc = ev.run_scenario(&clean).unwrap();
    assert_eq!(clean_acc.repeats, 1);
}

#[test]
fn native_scenario_driver_end_to_end() {
    // the exact path of `hybridac scenario --name paper-hybrid --model
    // synthetic --backend native`: accuracy + hardware estimation, with no
    // PJRT engine anywhere on the call path
    let dir = synthetic_dir();
    let sc = Scenario::builtin("paper-hybrid", "synthetic")
        .unwrap()
        .with_backend(BackendKind::Native)
        .with_eval(24, 1);
    let rep = hybridac::coordinator::run_scenario(&dir, &sc, 8).unwrap();
    assert_eq!(rep.method, "HybridAC");
    assert!((0.0..=1.0).contains(&rep.accuracy_mean));
    assert!(rep.crossbars > 0, "hardware mapping must allocate crossbars");
    assert!(rep.exec_seconds > 0.0);
}

#[test]
fn native_batch_server_round_trip() {
    let dir = synthetic_dir();
    let data = DatasetBlob::load(&dir, "synthetic").unwrap();
    let sc = hybrid_scenario("synthetic");
    let server =
        BatchServer::start_scenario(dir.clone(), sc, Duration::from_millis(3)).unwrap();
    let per = data.image_elems();
    let rxs: Vec<_> = (0..24)
        .map(|i| {
            let idx = i % data.n;
            server.submit(data.images[idx * per..(idx + 1) * per].to_vec())
        })
        .collect();
    for rx in rxs {
        let pred = rx.recv().expect("every request answered");
        assert!((0..10).contains(&pred), "prediction {pred} out of class range");
    }
    server.shutdown().unwrap();
}

#[test]
fn native_fleet_compiles_each_graph_variant_exactly_once() {
    let dir = synthetic_dir();
    let data = Arc::new(DatasetBlob::load(&dir, "synthetic").unwrap());
    let sc = hybrid_scenario("synthetic");
    let mut fleet = FleetConfig::new(4);
    fleet.max_wait = Duration::from_millis(2);
    let router = Arc::new(Router::start_scenario(dir.clone(), sc, fleet).unwrap());

    // the headline cache property: 4 replicas, 1 graph variant, exactly 1
    // compilation through the fleet-shared CompiledGraphCache
    assert_eq!(
        router.compiled_graphs(),
        Some(1),
        "a 4-replica native fleet must compile the variant once, not 4 times"
    );

    // every replica holds an independent variation draw
    let fm = router.fleet_metrics();
    assert_eq!(fm.replicas.len(), 4);
    for (i, a) in fm.replicas.iter().enumerate() {
        assert!(a.alive, "replica {i} died");
        for b in fm.replicas.iter().skip(i + 1) {
            assert_ne!(
                a.fingerprint, b.fingerprint,
                "replicas {} and {} share a variation draw",
                a.id, b.id
            );
        }
    }

    // the fleet serves traffic end-to-end
    let (_hits, total) = drive_workload(&router, &data, 64, 4).unwrap();
    assert_eq!(total, 64, "every request must be answered");
    assert_eq!(router.compiled_graphs(), Some(1), "serving must not recompile");
    Arc::try_unwrap(router).ok().unwrap().shutdown().unwrap();
}

#[test]
fn native_recycle_redraws_without_recompiling() {
    let dir = synthetic_dir();
    let data = DatasetBlob::load(&dir, "synthetic").unwrap();
    let sc = hybrid_scenario("synthetic");
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(2);
    // an unreachable accuracy floor flags any replica as degraded
    fleet.health = HealthPolicy { accuracy_floor: 1.01, min_probes: 8 };
    let router = Router::start_scenario(dir.clone(), sc, fleet).unwrap();

    let before = router.fleet_metrics().replicas[0].clone();
    router.probe(&data, 16);
    assert_eq!(router.fleet_metrics().replicas[0].status, HealthStatus::Degraded);

    let recycled = router.recycle_degraded().unwrap();
    assert_eq!(recycled, vec![0]);
    let after = router.fleet_metrics().replicas[0].clone();
    assert_eq!(after.generation, before.generation + 1);
    assert_ne!(after.fingerprint, before.fingerprint, "recycle must redraw variation");
    // the recycled replica reuses the fleet-shared compiled graph
    assert_eq!(router.compiled_graphs(), Some(1), "recycling must not recompile");

    let per = data.image_elems();
    let rx = router.submit(data.images[..per].to_vec()).unwrap();
    assert!(rx.recv().is_ok(), "recycled replica serves traffic");
    router.shutdown().unwrap();
}

#[test]
fn executor_accuracy_is_deterministic_on_native() {
    let dir = synthetic_dir();
    let art = Artifact::load(&dir, "synthetic").unwrap();
    let data = DatasetBlob::load(&dir, "synthetic").unwrap();
    let sc = hybrid_scenario("synthetic");
    let model = prepared(&art, &sc);
    let backend = BackendKind::Native.create().unwrap();
    let exec = ModelExecutor::new(backend.as_ref(), &art, &data, 32, art.group).unwrap();
    let a1 = exec.accuracy(&model).unwrap();
    let a2 = exec.accuracy(&model).unwrap();
    assert_eq!(a1, a2, "same instance must score identically");
    assert!((0.0..=1.0).contains(&a1));
}

// ---------------------------------------------------------------------------
// pjrt backend: the same contract, behind the usual artifact gate

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_conformance_over_real_artifacts() {
    use hybridac::tensor::argmax_rows;

    let dir = hybridac::artifacts_dir();
    if !dir.join("vggmini_c10s.meta.json").exists() {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        return;
    }
    let backend = match BackendKind::PjrtCpu.create() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("[skip] pjrt backend unavailable: {e:#}");
            return;
        }
    };
    let art = Artifact::load(&dir, "vggmini_c10s").unwrap();
    let data = DatasetBlob::load(&dir, &art.dataset).unwrap();
    let sc = Scenario::paper_default("conformance", "vggmini_c10s", Method::Hybrid { frac: 0.16 });
    let model = prepared(&art, &sc);

    // determinism + compile-once, the same checks the native leg runs
    let l1 = run_one_batch(backend.as_ref(), &art, &data, &model, false);
    let l2 = run_one_batch(backend.as_ref(), &art, &data, &model, false);
    assert_eq!(l1.len(), art.batch * art.num_classes);
    let diff = max_abs_diff(&l1, &l2);
    assert!(diff <= 1e-4, "pjrt reruns diverged by {diff}");
    assert_eq!(backend.compiled_graphs(), 1, "second run must hit the graph cache");

    // cross-backend: the native interpreter runs the same real artifact;
    // f32 summation order and ADC rounding boundaries differ, so compare
    // predictions, not bits
    let native = BackendKind::Native.create().unwrap();
    let ln = run_one_batch(native.as_ref(), &art, &data, &model, false);
    let pp = argmax_rows(&l1, art.num_classes);
    let pn = argmax_rows(&ln, art.num_classes);
    let agree = pp.iter().zip(&pn).filter(|(a, b)| a == b).count();
    assert!(
        agree * 10 >= pp.len() * 9,
        "native and pjrt predictions agree on only {agree}/{} rows",
        pp.len()
    );
}
