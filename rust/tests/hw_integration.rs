//! Hardware-model integration: mapping + timing over real artifacts and
//! cross-architecture sanity (the Fig. 9/10 orderings).

use hybridac::analog::AnalogTiming;
use hybridac::hwmodel::tile::TileModel;
use hybridac::hwmodel::{all_architectures, arch};
use hybridac::mapping::{balanced_digital_fraction, map_model, simulate_exec, MapScheme};
use hybridac::runtime::Artifact;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = hybridac::artifacts_dir();
    if dir.join("resnet18m_c10s.meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        None
    }
}

#[test]
fn hybrid_mapping_uses_fewer_crossbars() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "resnet18m_c10s").unwrap();
    let all_analog = map_model(&art, MapScheme::AllAnalog, 0.0);
    let hybrid = map_model(&art, MapScheme::Hybrid, 0.16);
    let iws = map_model(&art, MapScheme::IwsHoles, 0.16);
    assert!(
        hybrid.total_crossbars < all_analog.total_crossbars,
        "row removal + 6-bit cells must shrink the crossbar count: {} vs {}",
        hybrid.total_crossbars,
        all_analog.total_crossbars
    );
    assert!(
        iws.total_crossbars > all_analog.total_crossbars,
        "IWS zero holes must add crossbars: {} vs {}",
        iws.total_crossbars,
        all_analog.total_crossbars
    );
    assert!(iws.total_overhead_crossbars > 0);
    // The digital MAC fraction exceeds the 16% *weight* fraction on the
    // scaled models: sensitive channels concentrate in early layers, which
    // carry many more output pixels per weight (16x16 vs 4x4). The paper's
    // §5.4.2 balance argument equates the two only for its deep, large
    // models. Bound it loosely and positively.
    assert!(
        hybrid.digital_frac > 0.10 && hybrid.digital_frac < 0.75,
        "{}",
        hybrid.digital_frac
    );
}

#[test]
fn fig9_orderings_hold() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "resnet18m_c10s").unwrap();
    let batch = 250;
    let isaac_tile = TileModel::isaac();
    let hybrid_tile = TileModel::hybridac();
    let m_all = map_model(&art, MapScheme::AllAnalog, 0.0);
    let m_iws = map_model(&art, MapScheme::IwsHoles, 0.16);
    let m_h16 = map_model(&art, MapScheme::Hybrid, 0.16);
    let m_h10 = map_model(&art, MapScheme::Hybrid, 0.10);

    let isaac = simulate_exec(&m_all, &AnalogTiming::isaac(), &isaac_tile, 168,
                              batch, 0, 0.0, false);
    let iws1 = simulate_exec(&m_iws, &AnalogTiming::isaac(), &isaac_tile, 1,
                             batch, 128, 25.52, true);
    let iws2 = simulate_exec(&m_iws, &AnalogTiming::isaac(), &isaac_tile, 142,
                             batch, 128, 25.52, false);
    let h16 = simulate_exec(&m_h16, &AnalogTiming::hybridac(), &hybrid_tile, 148,
                            batch, 152, 1.788, false);
    let h10 = simulate_exec(&m_h10, &AnalogTiming::hybridac(), &hybrid_tile, 148,
                            batch, 95, 1.118, false);

    // paper's qualitative orderings (§5.4.3)
    assert!(h16.seconds < isaac.seconds, "HybridAC-16% beats ISAAC");
    assert!(iws1.seconds > isaac.seconds, "IWS-1 slower than ISAAC");
    assert!(iws1.seconds > iws2.seconds, "IWS-1 slower than IWS-2");
    assert!(h16.seconds <= h10.seconds, "balanced config at least as fast");
    assert!(h16.energy_j < isaac.energy_j, "HybridAC saves energy");
    assert!(iws1.reprogram_seconds > 0.0);
    assert_eq!(isaac.reprogram_seconds, 0.0);
}

#[test]
fn architectures_all_positive_and_isaac_anchor() {
    let archs = all_architectures();
    assert_eq!(archs.len(), 13);
    for a in &archs {
        assert!(a.peak_gops > 0.0, "{}", a.name);
        assert!(a.totals.area_mm2 > 0.0, "{}", a.name);
        assert!(a.totals.power_mw > 0.0, "{}", a.name);
    }
    let isaac = arch::by_name("Ideal-ISAAC").unwrap();
    assert!((isaac.area_eff() - 1912.0).abs() < 2.0);
}

#[test]
fn balanced_fraction_from_measured_efficiencies() {
    let hy = arch::by_name("HybridAC").unwrap();
    let analog_eff = (hy.peak_gops - hy.digital_gops) / hy.totals.analog_area_mm2;
    let digital_eff = hy.digital_gops / hy.totals.digital_area_mm2;
    let f = balanced_digital_fraction(analog_eff, digital_eff);
    assert!(f > 0.05 && f < 0.30, "balanced digital fraction {f}");
}

#[test]
fn reprogram_time_dominates_iws1_seconds() {
    let Some(dir) = artifacts() else { return };
    let art = Artifact::load(&dir, "resnet18m_c10s").unwrap();
    let m_iws = map_model(&art, MapScheme::IwsHoles, 0.16);
    let est = simulate_exec(&m_iws, &AnalogTiming::isaac(), &TileModel::isaac(), 1,
                            250, 128, 25.52, true);
    assert!(est.reprogram_seconds > 0.0);
    assert!(est.seconds >= est.reprogram_seconds);
}
