//! Pipeline-equivalence tests: the composable `PreparePipeline` must
//! reproduce the pre-pipeline monolithic `prepare()` (kept as
//! `reference_prepare`) **bit-for-bit** — same weights, same ADC params,
//! same RNG consumption — for the paper-default configs across all four
//! `Method`s, plus the cell/ADC variants the benches exercise.
//!
//! Runs on `Artifact::synthetic`, so no built artifacts are needed and the
//! suite executes in every CI run.

use hybridac::eval::prepare::{prepare, reference_prepare, ExperimentConfig, Method};
use hybridac::noise::CellModel;
use hybridac::quantize::QuantConfig;
use hybridac::runtime::executor::PreparedModel;
use hybridac::runtime::Artifact;
use hybridac::scenario::{PerturbSpec, Scenario};
use hybridac::util::rng::Rng;

fn assert_bitwise_eq(a: &PreparedModel, b: &PreparedModel, label: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{label}: layer count");
    for (li, (x, y)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (name, ta, tb) in [
            ("wa1", &x.wa1, &y.wa1),
            ("wa2", &x.wa2, &y.wa2),
            ("wd", &x.wd, &y.wd),
            ("bias", &x.bias, &y.bias),
        ] {
            assert_eq!(ta.shape, tb.shape, "{label}: layer {li} {name} shape");
            let same = ta
                .data
                .iter()
                .zip(&tb.data)
                .all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(same, "{label}: layer {li} {name} differs bitwise");
        }
        assert_eq!(x.lsb.to_bits(), y.lsb.to_bits(), "{label}: layer {li} lsb");
        assert_eq!(x.clip.to_bits(), y.clip.to_bits(), "{label}: layer {li} clip");
    }
}

/// Old implementation vs the pipeline route of `prepare()` vs an explicit
/// `Scenario` lowering — all three must agree bit-for-bit, and consume the
/// RNG identically (checked by comparing the next draw afterwards).
fn check_equivalent(art: &Artifact, cfg: &ExperimentConfig, label: &str) {
    let mut r_ref = Rng::new(cfg.seed);
    let reference = reference_prepare(art, cfg, &mut r_ref);

    let mut r_new = Rng::new(cfg.seed);
    let piped = prepare(art, cfg, &mut r_new);
    assert_bitwise_eq(&reference, &piped, label);

    let mut r_sc = Rng::new(cfg.seed);
    let scenario = Scenario::from_config(label, &art.tag, cfg);
    let from_spec = scenario.pipeline().prepare(art, &mut r_sc);
    assert_bitwise_eq(&reference, &from_spec, &format!("{label} (via Scenario)"));

    // identical post-prepare draws ⇒ every path consumed the RNG equally
    // (an under- or over-draw would desynchronize the streams here)
    let expect = r_ref.next_u64();
    assert_eq!(r_new.next_u64(), expect, "{label}: pipeline RNG consumption differs");
    assert_eq!(r_sc.next_u64(), expect, "{label}: scenario RNG consumption differs");
}

#[test]
fn pipeline_matches_reference_for_all_paper_default_methods() {
    let art = Artifact::synthetic(42);
    for method in [
        Method::Clean,
        Method::NoProtection,
        Method::Iws { frac: 0.2 },
        Method::Hybrid { frac: 0.16 },
    ] {
        let cfg = ExperimentConfig::paper_default(method.clone());
        check_equivalent(&art, &cfg, &format!("{method:?}"));
    }
}

#[test]
fn pipeline_matches_reference_for_differential_cells_and_low_adc() {
    let art = Artifact::synthetic(7);
    for method in [Method::NoProtection, Method::Iws { frac: 0.1 }, Method::Hybrid { frac: 0.16 }] {
        let mut cfg = ExperimentConfig::paper_default(method.clone()).with_adc(4);
        cfg.cell = CellModel::differential(0.5);
        check_equivalent(&art, &cfg, &format!("differential {method:?}"));
    }
}

#[test]
fn pipeline_matches_reference_for_ideal_readout_and_quant_variants() {
    let art = Artifact::synthetic(9);
    let mut no_adc = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
    no_adc.adc_bits = None;
    check_equivalent(&art, &no_adc, "no-adc");

    let mut no_quant = ExperimentConfig::paper_default(Method::Iws { frac: 0.12 });
    no_quant.quant = None;
    check_equivalent(&art, &no_quant, "no-quant");

    let hybrid_quant = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 })
        .with_quant(QuantConfig::hybrid())
        .with_adc(6);
    check_equivalent(&art, &hybrid_quant, "hybrid-quant-6b");

    let mut no_digital = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
    no_digital.sigma_digital = 0.0; // old code skipped the digital perturb entirely
    check_equivalent(&art, &no_digital, "sigma-digital-zero");
}

#[test]
fn pipeline_matches_reference_across_seeds_and_groups() {
    let art = Artifact::synthetic(11);
    for seed in [1u64, 0xD1CE, 0xFEED_BEEF] {
        for group in [16usize, 128] {
            let mut cfg = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
            cfg.seed = seed;
            cfg.group = group;
            check_equivalent(&art, &cfg, &format!("seed {seed} group {group}"));
        }
    }
}

/// The new perturbations must actually do something: a stuck-at stage and a
/// drift stage each change the prepared analog weights relative to the
/// paper-default pipeline, without touching the digital copy.
#[test]
fn extra_perturbations_change_analog_weights_only() {
    let art = Artifact::synthetic(13);
    let base = Scenario::paper_default("base", "synthetic", Method::Hybrid { frac: 0.16 });
    let faulty = base.clone().with_stage(PerturbSpec::StuckAt { rate: 0.05 });
    let drifted = base.clone().with_stage(PerturbSpec::Drift {
        t_seconds: 3600.0 * 24.0,
        nu: 0.08,
        nu_sigma: 0.0,
    });

    let m_base = base.pipeline().prepare(&art, &mut Rng::new(1));
    for (name, sc) in [("stuck-at", &faulty), ("drift", &drifted)] {
        let m = sc.pipeline().prepare(&art, &mut Rng::new(1));
        // pinned layer 0 is all-digital: its analog copy is empty either way
        let changed = m
            .layers
            .iter()
            .zip(&m_base.layers)
            .any(|(a, b)| a.wa1.data != b.wa1.data);
        assert!(changed, "{name} stage must alter the analog weights");
        // within one layer the extra stage runs after both variation
        // stages, so through the first fault-carrying layer (layer 1; the
        // pinned layer 0 has an empty analog copy) the digital copies'
        // draws are identical to the base run — the stage itself never
        // touches wd. Later layers see a shifted RNG stream, which is
        // expected.
        for li in 0..2 {
            assert_eq!(
                m.layers[li].wd.data, m_base.layers[li].wd.data,
                "{name}: layer {li} digital copy must be untouched"
            );
        }
    }
}

/// A scenario is the unit of serving too: same seed ⇒ same instance, and
/// the spec survives a JSON round trip with the prepared output unchanged.
#[test]
fn scenario_prepare_is_deterministic_and_json_stable() {
    let art = Artifact::synthetic(17);
    let sc = Scenario::paper_default("det", "synthetic", Method::Hybrid { frac: 0.16 })
        .with_stage(PerturbSpec::StuckAt { rate: 0.01 })
        .with_seed(0xABCD);
    let a = sc.pipeline().prepare(&art, &mut Rng::new(sc.seed));
    let b = sc.pipeline().prepare(&art, &mut Rng::new(sc.seed));
    assert_bitwise_eq(&a, &b, "same scenario, same seed");

    let roundtripped = Scenario::parse(&sc.to_json().to_string()).unwrap();
    let c = roundtripped.pipeline().prepare(&art, &mut Rng::new(roundtripped.seed));
    assert_bitwise_eq(&a, &c, "scenario after JSON round trip");

    let other = sc.pipeline().prepare(&art, &mut Rng::new(0x1234));
    let differs = a
        .layers
        .iter()
        .zip(&other.layers)
        .any(|(x, y)| x.wa1.data != y.wa1.data);
    assert!(differs, "different seeds must give different draws");
}
