//! Incremental-prepare properties: the cached deterministic base + the
//! per-repeat perturbation delta must be *bit-identical* to the full
//! pipeline — at the tensor level, at the end-to-end accuracy level with
//! the cache forced on vs off, and with the study runner's shared cache
//! demonstrably collapsing sigma-axis points onto one base entry.
//!
//! Everything here runs with no built artifacts and no xla (synthetic
//! artifact + native backend), in both the default and the
//! `--no-default-features` builds.

use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

use hybridac::eval::{Evaluator, Method};
use hybridac::exec::BackendKind;
use hybridac::runtime::Artifact;
use hybridac::scenario::{PerturbSpec, PreparedBaseCache, Scenario};
use hybridac::study::{Axis, Study, StudyRunner};
use hybridac::util::rng::Rng;

/// Materialize the synthetic artifact + dataset once per test process.
fn synthetic_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("hybridac-prepcache-{}", std::process::id()));
        Artifact::materialize_synthetic(&dir).expect("materialize synthetic artifact");
        dir
    })
    .clone()
}

/// The scenario matrix the incremental path must reproduce exactly:
/// analog-only perturbations (paper default), differential cells,
/// stuck-at faults + drift (extra analog stages), and a digital-only
/// perturbation (the `wa` panels must alias the base untouched).
fn scenarios() -> Vec<Scenario> {
    let native = |sc: Scenario| sc.with_backend(BackendKind::Native).with_eval(32, 3);
    let mut digital_only =
        Scenario::paper_default("digital-noise", "synthetic", Method::Hybrid { frac: 0.16 });
    digital_only.perturb = vec![PerturbSpec::DigitalVariation { sigma: 0.05 }];
    vec![
        native(Scenario::paper_default(
            "paper-hybrid",
            "synthetic",
            Method::Hybrid { frac: 0.16 },
        )),
        native(Scenario::builtin("differential-4b", "synthetic").unwrap()),
        native(Scenario::builtin("stuck-at", "synthetic").unwrap()),
        native(Scenario::builtin("drift-1h", "synthetic").unwrap()),
        native(digital_only),
    ]
}

fn bits(t: &hybridac::tensor::Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn base_plus_delta_matches_full_prepare_bit_for_bit() {
    let art = Artifact::synthetic(42);
    for sc in scenarios() {
        let pipeline = sc.pipeline();
        let base = pipeline.prepare_base(&art);
        // one shared RNG per path, forked per repeat exactly like the
        // evaluator's loop — the delta must consume the same stream
        let mut master_full = Rng::new(sc.seed);
        let mut master_delta = Rng::new(sc.seed);
        for rep in 0..3u64 {
            let mut rng_full = master_full.fork(rep + 1);
            let mut rng_delta = master_delta.fork(rep + 1);
            let full = pipeline.prepare(&art, &mut rng_full);
            let inst = pipeline.prepare_delta(&base, &art, &mut rng_delta);
            assert_eq!(full.layers.len(), inst.layers.len(), "{}", sc.name);
            for (li, (f, d)) in full.layers.iter().zip(&inst.layers).enumerate() {
                let tag = format!("{} layer {li} rep {rep}", sc.name);
                assert_eq!(bits(&f.wa1), bits(&d.wa1), "wa1 {tag}");
                assert_eq!(bits(&f.wa2), bits(&d.wa2), "wa2 {tag}");
                assert_eq!(bits(&f.wd), bits(&d.wd), "wd {tag}");
                assert_eq!(bits(&f.bias), bits(&d.bias), "bias {tag}");
                assert_eq!(f.lsb.to_bits(), d.lsb.to_bits(), "lsb {tag}");
                assert_eq!(f.clip.to_bits(), d.clip.to_bits(), "clip {tag}");
            }
        }
    }
}

#[test]
fn accuracy_is_bit_identical_cache_on_vs_off() {
    let dir = synthetic_dir();
    for sc in scenarios() {
        assert!(sc.repeats >= 3, "{}: the pin needs repeats >= 3", sc.name);
        let on = Evaluator::for_scenario(&dir, &sc).unwrap();
        let off = Evaluator::for_scenario(&dir, &sc).unwrap().with_base_cache(None);
        let a = on.run_scenario(&sc).unwrap();
        let b = off.run_scenario(&sc).unwrap();
        assert_eq!(a.repeats, b.repeats, "{}", sc.name);
        assert_eq!(
            a.mean.to_bits(),
            b.mean.to_bits(),
            "{}: cached mean {} != uncached {}",
            sc.name,
            a.mean,
            b.mean
        );
        assert_eq!(
            a.std.to_bits(),
            b.std.to_bits(),
            "{}: cached std {} != uncached {}",
            sc.name,
            a.std,
            b.std
        );
    }
}

fn sigma_study(name: &str) -> Study {
    Study {
        name: name.to_string(),
        base: Scenario::paper_default(name, "synthetic", Method::Hybrid { frac: 0.16 })
            .with_backend(BackendKind::Native)
            .with_eval(32, 3),
        axes: vec![Axis::Sigma(vec![0.25, 0.5, 0.75])],
    }
}

#[test]
fn sigma_axis_points_share_one_base_entry() {
    let dir = synthetic_dir();
    let cache = Arc::new(PreparedBaseCache::new());
    let rep = StudyRunner::new(&dir)
        .with_workers(1)
        .with_base_cache(cache.clone())
        .run(&sigma_study("sigma-share"))
        .unwrap();
    assert_eq!(rep.points.len(), 3);
    // two distinct bases live in the cache: the clean anchor's (no split,
    // no quant) and the one shared by all three sigma points — sigma only
    // changes the perturbation stage, never the base key
    assert_eq!(cache.len(), 2, "clean anchor + one shared point base");
    assert_eq!(cache.misses(), 2, "each distinct base builds exactly once");
    assert_eq!(cache.hits(), 2, "the 2nd and 3rd sigma points hit the shared base");
}

#[test]
fn study_report_is_byte_identical_cache_on_vs_off() {
    let dir = synthetic_dir();
    let on = StudyRunner::new(&dir)
        .with_workers(1)
        .run(&sigma_study("cache-on-off"))
        .unwrap();
    let off = StudyRunner::new(&dir)
        .with_workers(1)
        .with_prepare_cache(false)
        .run(&sigma_study("cache-on-off"))
        .unwrap();
    assert_eq!(
        on.to_json().to_string(),
        off.to_json().to_string(),
        "the prepare cache must never change a study report"
    );
}
