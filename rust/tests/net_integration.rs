//! Integration tests for the networked serving front door: wire-protocol
//! robustness (malformed frames, oversized payloads, mid-request
//! disconnects must surface as typed errors, never as hung connections or
//! leaked admission-queue slots) and fleet elasticity (the autoscaler
//! grows under sustained load and shrinks back to the minimum when it
//! stops).
//!
//! Everything runs on the materialized synthetic artifact with the native
//! backend, so these tests need no built artifacts and run in both CI
//! feature configurations.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hybridac::eval::Method;
use hybridac::exec::BackendKind;
use hybridac::net::{
    FrameError, FrameReader, InferOutcome, NetClient, NetServer, Request, Response, ServerConfig,
    KIND_BAD_FRAME, MAX_FRAME,
};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::scenario::Scenario;
use hybridac::serve::{AutoscaleConfig, FleetConfig, Router};

/// Synthetic-artifact fleet + listener; `name` keeps parallel tests out of
/// each other's artifact directories.
fn start(
    name: &str,
    fleet: FleetConfig,
    cfg: ServerConfig,
) -> (Arc<Router>, Arc<DatasetBlob>, NetServer) {
    let dir = std::env::temp_dir().join(format!("hybridac-net-{name}-{}", std::process::id()));
    Artifact::materialize_synthetic(&dir).unwrap();
    let art = Artifact::load(&dir, "synthetic").unwrap();
    let data = Arc::new(DatasetBlob::load(&dir, &art.dataset).unwrap());
    let sc = Scenario::paper_default(name, "synthetic", Method::Hybrid { frac: 0.16 })
        .with_backend(BackendKind::Native)
        .with_threads(1);
    let router = Arc::new(Router::start_scenario(dir, sc, fleet).unwrap());
    let server = NetServer::bind("127.0.0.1:0", router.clone(), cfg).unwrap();
    (router, data, server)
}

fn stop(router: Arc<Router>, server: NetServer) {
    server.shutdown().unwrap();
    Arc::try_unwrap(router).ok().expect("router still referenced").shutdown().unwrap();
}

/// Raw frame writer: lets tests send payloads `write_frame` never would.
fn raw_frame(stream: &mut TcpStream, payload: &[u8]) {
    stream.write_all(&(payload.len() as u32).to_be_bytes()).unwrap();
    stream.write_all(payload).unwrap();
    stream.flush().unwrap();
}

/// Next response frame, with a deadline so a server bug fails the test
/// instead of hanging it (the test sockets carry a short read timeout).
fn read_response(r: &mut FrameReader<TcpStream>) -> Response {
    let t0 = Instant::now();
    loop {
        match r.poll() {
            Ok(Some(j)) => return Response::from_json(&j).expect("decodable response"),
            Ok(None) => assert!(t0.elapsed() < Duration::from_secs(10), "no response within 10s"),
            Err(e) => panic!("transport error while waiting for a response: {e}"),
        }
    }
}

/// Assert the server closed the connection (clean EOF or a reset).
fn expect_closed(r: &mut FrameReader<TcpStream>) {
    let t0 = Instant::now();
    loop {
        match r.poll() {
            Ok(Some(j)) => panic!("unexpected frame after close: {j:?}"),
            Ok(None) => {
                assert!(t0.elapsed() < Duration::from_secs(10), "connection not closed within 10s")
            }
            Err(FrameError::Eof | FrameError::Truncated | FrameError::Io(_)) => return,
            Err(e) => panic!("unexpected error waiting for close: {e}"),
        }
    }
}

fn raw_conn(addr: std::net::SocketAddr) -> (TcpStream, FrameReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let reader = FrameReader::new(stream.try_clone().unwrap(), MAX_FRAME);
    (stream, reader)
}

/// Garbage and wrong-shape frames come back as typed `bad_frame` errors
/// and the same connection keeps serving real traffic afterwards.
#[test]
fn malformed_frames_get_typed_errors_and_the_connection_keeps_serving() {
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(2);
    let (router, data, server) = start("badframe", fleet, ServerConfig::default());
    let (mut stream, mut reader) = raw_conn(server.local_addr());

    // unparseable payload: framing is still aligned, so it's an answer
    raw_frame(&mut stream, b"{not json");
    match read_response(&mut reader) {
        Response::Error { id, kind, .. } => {
            assert_eq!(kind, KIND_BAD_FRAME);
            assert_eq!(id, 0, "no id was decodable");
        }
        other => panic!("expected bad_frame error, got {other:?}"),
    }

    // valid JSON, wrong shape: still bad_frame, and the id is echoed back
    raw_frame(&mut stream, br#"{"type":"warp","id":9}"#);
    match read_response(&mut reader) {
        Response::Error { id, kind, message } => {
            assert_eq!(kind, KIND_BAD_FRAME);
            assert_eq!(id, 9);
            assert!(message.contains("warp"), "error names the problem: {message}");
        }
        other => panic!("expected bad_frame error, got {other:?}"),
    }

    // the connection is not poisoned: a ping and a real inference work
    let mut w = stream.try_clone().unwrap();
    hybridac::net::wire::write_frame(&mut w, &Request::Ping { id: 3 }.to_json()).unwrap();
    assert_eq!(read_response(&mut reader), Response::Pong { id: 3 });
    let per = data.image_elems();
    let image = data.images[..per].to_vec();
    hybridac::net::wire::write_frame(&mut w, &Request::Infer { id: 4, image }.to_json()).unwrap();
    assert!(matches!(read_response(&mut reader), Response::Result { id: 4, .. }));

    // admission refusals are typed answers too, and don't end the session
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let too_big = vec![0.0f32; per + 1];
    match client.infer(&too_big).unwrap() {
        InferOutcome::Denied { kind, .. } => assert_eq!(kind, "bad_request"),
        other => panic!("wrong-size image must be denied, got {other:?}"),
    }
    assert!(matches!(client.infer(&data.images[..per]).unwrap(), InferOutcome::Pred(_)));

    drop(stream);
    stop(router, server);
}

/// An oversized declared length gets one final typed error, then the
/// connection closes (the unread payload makes the stream unrecoverable);
/// the listener keeps accepting everyone else.
#[test]
fn oversized_frame_gets_a_final_error_then_the_connection_closes() {
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(2);
    let cfg = ServerConfig { max_frame: 1024, ..ServerConfig::default() };
    let (router, data, server) = start("oversize", fleet, cfg);

    let (mut stream, mut reader) = raw_conn(server.local_addr());
    stream.write_all(&(8u32 << 20).to_be_bytes()).unwrap();
    stream.flush().unwrap();
    match read_response(&mut reader) {
        Response::Error { kind, message, .. } => {
            assert_eq!(kind, KIND_BAD_FRAME);
            assert!(message.contains("1024"), "error cites the cap: {message}");
        }
        other => panic!("expected bad_frame error, got {other:?}"),
    }
    expect_closed(&mut reader);

    // that client's misbehavior was contained to its connection
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    let per = data.image_elems();
    assert!(matches!(client.infer(&data.images[..per]).unwrap(), InferOutcome::Pred(_)));
    stop(router, server);
}

/// A client that vanishes mid-frame — with a request already admitted —
/// must not leak an admission-queue slot or wedge the fleet.
#[test]
fn mid_request_disconnect_leaks_no_queue_slots() {
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(2);
    fleet.queue_depth = 4;
    let (router, data, server) = start("disconnect", fleet, ServerConfig::default());
    let per = data.image_elems();

    {
        let (mut stream, _reader) = raw_conn(server.local_addr());
        // one admitted request, then a partial frame, then gone
        let image = data.images[..per].to_vec();
        let mut w = stream.try_clone().unwrap();
        hybridac::net::wire::write_frame(&mut w, &Request::Infer { id: 1, image }.to_json())
            .unwrap();
        stream.write_all(&100u32.to_be_bytes()).unwrap();
        stream.write_all(b"only-ten-b").unwrap();
        stream.flush().unwrap();
        // dropping both halves closes the socket mid-frame
    }

    // the admitted request still drains; the gauge must return to zero
    let t0 = Instant::now();
    loop {
        let depth = router.fleet_metrics().total.queue_depth;
        if depth == 0 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "queue slot leaked: depth {depth}");
        std::thread::sleep(Duration::from_millis(50));
    }

    // fleet and listener keep serving new connections at full capacity
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    for i in 0..4 {
        let idx = i % data.n;
        let image = &data.images[idx * per..(idx + 1) * per];
        assert!(matches!(client.infer(image).unwrap(), InferOutcome::Pred(_)));
    }
    let fm = router.fleet_metrics();
    assert!(fm.total.requests >= 5, "admitted requests were all served: {}", fm.total.requests);
    stop(router, server);
}

/// Pipelined requests get their responses strictly in request order.
#[test]
fn pipelined_requests_answered_in_order() {
    let mut fleet = FleetConfig::new(2);
    fleet.max_wait = Duration::from_millis(2);
    fleet.queue_depth = 32;
    let (router, data, server) = start("pipeline", fleet, ServerConfig::default());
    let per = data.image_elems();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let ids: Vec<u64> = (0..16)
        .map(|i| {
            let idx = i % data.n;
            client.send_infer(&data.images[idx * per..(idx + 1) * per]).unwrap()
        })
        .collect();
    for id in ids {
        match client.recv().unwrap() {
            Response::Result { id: got, .. } | Response::Error { id: got, .. } => {
                assert_eq!(got, id, "responses must arrive in request order")
            }
            other => panic!("unexpected response {other:?}"),
        }
    }
    stop(router, server);
}

/// The elasticity contract end to end: under sustained network load the
/// autoscaler grows the fleet and the shed fraction falls; when the load
/// stops it drains back down to the configured minimum.
#[test]
fn autoscaler_grows_under_load_and_shrinks_back_to_min() {
    let mut fleet = FleetConfig::new(1);
    fleet.max_wait = Duration::from_millis(1);
    fleet.queue_depth = 2;
    fleet = fleet.with_bounds(1, 3).with_autoscale(AutoscaleConfig {
        interval: Duration::from_millis(50),
        up_after: 2,
        down_after: 3,
        ..AutoscaleConfig::default()
    });
    let (router, data, server) = start("elastic", fleet, ServerConfig::default());
    assert_eq!(router.active_replicas(), 1);
    assert!(router.has_autoscaler());

    // hammer the listener from closed-loop clients; each records
    // (elapsed seconds, was_shed) per request
    let stop_flag = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let addr = server.local_addr();
    let workers: Vec<_> = (0..6)
        .map(|c| {
            let data = data.clone();
            let stop_flag = stop_flag.clone();
            std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                let per = data.image_elems();
                let mut log: Vec<(f64, bool)> = Vec::new();
                let mut j = 0usize;
                while !stop_flag.load(Ordering::Relaxed) {
                    let idx = (c + j * 6) % data.n;
                    let image = &data.images[idx * per..(idx + 1) * per];
                    let shed = match client.infer(image).unwrap() {
                        InferOutcome::Pred(_) => false,
                        InferOutcome::Denied { .. } => true,
                    };
                    log.push((t0.elapsed().as_secs_f64(), shed));
                    j += 1;
                }
                log
            })
        })
        .collect();

    // growth: sustained pressure must add replicas
    let grow_deadline = Duration::from_secs(10);
    let grown_at = loop {
        if router.active_replicas() >= 2 {
            break t0.elapsed().as_secs_f64();
        }
        assert!(t0.elapsed() < grow_deadline, "autoscaler never grew the fleet under load");
        std::thread::sleep(Duration::from_millis(20));
    };
    // keep the load on the bigger fleet long enough to compare shed rates
    std::thread::sleep(Duration::from_millis(1200));
    stop_flag.store(true, Ordering::Relaxed);
    let log: Vec<(f64, bool)> =
        workers.into_iter().flat_map(|w| w.join().expect("client thread panicked")).collect();

    let shed_fraction = |lo: f64, hi: f64| {
        let (mut sent, mut shed) = (0usize, 0usize);
        for &(t, s) in &log {
            if t >= lo && t < hi {
                sent += 1;
                shed += s as usize;
            }
        }
        (sent, shed as f64 / sent.max(1) as f64)
    };
    // before growth vs. well after it (0.3s settle): same offered pattern,
    // more capacity, fewer sheds
    let (sent_before, frac_before) = shed_fraction(0.0, grown_at);
    let (sent_after, frac_after) = shed_fraction(grown_at + 0.3, f64::INFINITY);
    assert!(sent_before > 0 && sent_after > 0, "both phases saw traffic");
    assert!(
        frac_before > 0.0,
        "a 6-client hammer against one depth-2 queue must shed (sent {sent_before})"
    );
    assert!(
        frac_after < frac_before,
        "shed fraction must fall after growth: {frac_before:.3} -> {frac_after:.3} \
         (sent {sent_before} -> {sent_after})"
    );

    // drain: with the load gone the fleet walks back to --min-replicas
    let t1 = Instant::now();
    while router.active_replicas() > 1 {
        assert!(
            t1.elapsed() < Duration::from_secs(15),
            "autoscaler never shrank back to min: {} replicas",
            router.active_replicas()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    let fm = router.fleet_metrics();
    assert!(fm.scale_ups >= 1, "growth recorded in fleet metrics");
    assert!(fm.scale_downs >= 1, "shrink recorded in fleet metrics");
    assert_eq!(fm.total.queue_depth, 0, "drained fleet holds no queued work");
    stop(router, server);
}
