//! Property fixtures for the in-tree tidy lint (`src/lint/`): every rule
//! catches its seeded true positive, a justified `tidy: allow` suppresses
//! it, the clean spelling passes — and the repo's own tree is clean.

use std::path::Path;

use hybridac::lint::{lint_file, rules, run};

/// Unsuppressed violations for `src` pretending to live at `path`.
fn violations(path: &str, src: &str) -> Vec<hybridac::lint::Violation> {
    lint_file(path, src).0
}

/// Assert the fixture yields exactly one violation of `rule`.
fn assert_one(path: &str, src: &str, rule: &str) {
    let v = violations(path, src);
    assert_eq!(v.len(), 1, "expected one {rule} violation in {path}, got {v:?}");
    assert_eq!(v[0].rule, rule, "wrong rule in {path}: {v:?}");
}

/// Assert the fixture is clean and (if `expect_suppressed`) that the
/// suppression was counted rather than the rule simply not firing.
fn assert_clean(path: &str, src: &str, expect_suppressed: bool) {
    let (v, suppressed) = lint_file(path, src);
    assert!(v.is_empty(), "expected clean {path}, got {v:?}");
    if expect_suppressed {
        assert!(suppressed >= 1, "allow directive in {path} never matched a violation");
    }
}

#[test]
fn determinism_fixtures() {
    let bad = "use std::collections::HashMap;\n";
    assert_one("src/study/report.rs", bad, rules::DETERMINISM);
    let set = "let s = std::collections::HashSet::new();\n";
    assert_one("benches/perf.rs", set, rules::DETERMINISM);
    let allowed =
        "let m = HashMap::new(); // tidy: allow(determinism): keys sorted before rendering\n";
    assert_clean("src/study/grid.rs", allowed, true);
    let clean = "use std::collections::BTreeMap;\nlet m: BTreeMap<u32, f64> = BTreeMap::new();\n";
    assert_clean("src/study/report.rs", clean, false);
    // out of scope: exec caches may hash freely
    assert_clean("src/exec/cache.rs", bad, false);
}

#[test]
fn float_order_fixtures() {
    let bad = "let y = a.mul_add(b, c);\n";
    assert_one("src/exec/native/kernels/x86.rs", bad, rules::FLOAT_ORDER);
    let fused = "let v = _mm256_fmadd_ps(a, b, c);\n";
    assert_one("src/exec/native/plan.rs", fused, rules::FLOAT_ORDER);
    let allowed =
        "let y = a.mul_add(b, c); // tidy: allow(float-order): diagnostics only, never compared\n";
    assert_clean("src/exec/native/plan.rs", allowed, true);
    let clean = "let y = a * b + c;\n";
    assert_clean("src/exec/native/kernels/x86.rs", clean, false);
    // reference.rs defines the rounding contract and may use whatever it likes
    assert_clean("src/exec/native/reference.rs", bad, false);
    // out of scope entirely
    assert_clean("src/analog/noise.rs", bad, false);
}

#[test]
fn panic_policy_fixtures() {
    assert_one("src/net/server.rs", "let v = rx.recv().unwrap();\n", rules::PANIC_POLICY);
    assert_one("src/serve/router.rs", "let g = m.lock().expect(\"lock\");\n", rules::PANIC_POLICY);
    assert_one("src/serve/admission.rs", "panic!(\"queue full\");\n", rules::PANIC_POLICY);
    let allowed =
        "// tidy: allow(panic-policy): startup-only; a bind failure must abort\nf().unwrap();\n";
    assert_clean("src/net/server.rs", allowed, true);
    let clean = "let v = rx.recv()?;\nlet g = mutex_lock(&m);\n";
    assert_clean("src/net/server.rs", clean, false);
    // out of scope: study code may unwrap
    assert_clean("src/study/runner.rs", "let v = rx.recv().unwrap();\n", false);
}

#[test]
fn unsafe_hygiene_fixtures() {
    let bad = "unsafe { *p }\n";
    assert_one("src/exec/native/kernels/x86.rs", bad, rules::UNSAFE_HYGIENE);
    // SAFETY on the comment line directly above attaches
    let clean = "// SAFETY: p points into the packed panel, ki < k\nunsafe { *p }\n";
    assert_clean("src/exec/native/kernels/neon.rs", clean, false);
    // a `/// # Safety` doc section above an unsafe fn attaches across attrs
    let doc_fn = "/// # Safety\n/// CPU must support avx2.\n\
                  #[target_feature(enable = \"avx2\")]\nunsafe fn adc() {}\n";
    assert_clean("src/exec/native/kernels/x86.rs", doc_fn, false);
    // #[target_feature] on a safe fn is a violation even with SAFETY nearby
    let tf_safe = "// SAFETY: fine\n#[target_feature(enable = \"avx2\")]\nfn adc() {}\n";
    assert_one("src/exec/native/kernels/x86.rs", tf_safe, rules::UNSAFE_HYGIENE);
    let allowed = "unsafe { *p } // tidy: allow(unsafe-hygiene): fixture for the lint tests\n";
    assert_clean("src/exec/native/kernels/x86.rs", allowed, true);
    // out of scope: dispatch sites elsewhere are clippy's problem
    assert_clean("src/exec/native/mod.rs", bad, false);
}

#[test]
fn clock_fixtures() {
    let bad = "let t0 = Instant::now();\n";
    assert_one("src/eval/evaluator.rs", bad, rules::CLOCK);
    assert_one("src/study/runner.rs", "let now = SystemTime::now();\n", rules::CLOCK);
    let allowed = "// tidy: allow(clock): timing side channel, never in reports\n\
                   let t0 = Instant::now();\n";
    assert_clean("src/study/runner.rs", allowed, true);
    // exempt homes for wall-clock reads
    assert_clean("src/obs/trace.rs", bad, false);
    assert_clean("src/serve/router.rs", bad, false);
    assert_clean("src/net/server.rs", bad, false);
    assert_clean("src/coordinator/batcher.rs", bad, false);
}

#[test]
fn obs_naming_fixtures() {
    assert_one("src/serve/metrics.rs", "let c = reg.counter(\"hits\");\n", rules::OBS_NAMING);
    assert_one("src/net/server.rs", "reg.counter(\"NetRequests_total\");\n", rules::OBS_NAMING);
    let allowed =
        "let c = reg.counter(\"hits\"); // tidy: allow(obs-naming): legacy dashboard series\n";
    assert_clean("src/serve/metrics.rs", allowed, true);
    assert_clean("src/net/server.rs", "reg.counter(\"net_requests_total\");\n", false);
}

#[test]
fn allow_syntax_is_policed_and_unsuppressible() {
    // bare allow: suppresses the underlying hit but is itself a violation
    let bare = "let t = Instant::now(); // tidy: allow(clock)\n";
    assert_one("src/eval/evaluator.rs", bare, rules::ALLOW_SYNTAX);
    // unknown rule name is a violation and suppresses nothing
    let unknown = "let t = Instant::now(); // tidy: allow(clocks): typo\n";
    let v = violations("src/eval/evaluator.rs", unknown);
    assert_eq!(v.len(), 2, "{v:?}");
    assert!(v.iter().any(|x| x.rule == rules::ALLOW_SYNTAX));
    assert!(v.iter().any(|x| x.rule == rules::CLOCK));
}

#[test]
fn test_code_is_exempt_from_every_rule() {
    let src = "fn live() {}\n\
               #[cfg(test)]\n\
               mod tests {\n\
               \x20   use std::collections::HashMap;\n\
               \x20   fn t() { foo.unwrap(); let t = Instant::now(); unsafe { *p } }\n\
               }\n";
    for path in
        ["src/study/report.rs", "src/serve/router.rs", "src/exec/native/kernels/x86.rs"]
    {
        assert_clean(path, src, false);
    }
}

/// The gate itself: the repo's own tree has zero unsuppressed violations.
#[test]
fn whole_tree_is_clean() {
    let report = run(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("lint run");
    assert!(
        report.violations.is_empty(),
        "tidy violations in tree:\n{}",
        report
            .violations
            .iter()
            .map(|v| format!("  {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 40, "suspiciously few files: {}", report.files_scanned);
    // the clock allows in eval/, study/, and main.rs must be live
    assert!(report.suppressed >= 8, "expected >=8 suppressions, got {}", report.suppressed);
}
