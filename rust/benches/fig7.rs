//! Fig. 7 — accuracy vs %protected weights on the ImageNet-analog dataset
//! (in50s): ResNet18, ResNet34, DenseNet121; HybridAC vs IWS curves.

use hybridac::benchkit::{built_combos, eval_budget, Stopwatch};
use hybridac::eval::{Evaluator, Method};
use hybridac::report;
use hybridac::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig7");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let points = [0.0, 0.04, 0.08, 0.12, 0.16, 0.20, 0.25];

    for (tag, pretty) in built_combos("in50s") {
        let mut ev = Evaluator::new(&dir, &tag)?;
        let clean = ev.clean_accuracy(n_eval)?;
        let mut hyb = Vec::new();
        let mut iws = Vec::new();
        for &p in &points {
            let ch = Scenario::paper_default("fig7", &tag, Method::Hybrid { frac: p })
                .with_eval(n_eval, repeats);
            let ci = Scenario::paper_default("fig7", &tag, Method::Iws { frac: p })
                .with_eval(n_eval, repeats);
            hyb.push(100.0 * ev.run_scenario(&ch)?.mean);
            iws.push(100.0 * ev.run_scenario(&ci)?.mean);
        }
        let xs: Vec<f64> = points.iter().map(|p| 100.0 * p).collect();
        print!(
            "{}",
            report::series_plot(
                &format!("Fig. 7 [{pretty}/in50s]: accuracy vs %protected (clean {:.1}%)",
                         100.0 * clean),
                "%protected",
                &xs,
                &[("HybridAC", hyb), ("IWS", iws)]
            )
        );
    }
    Ok(())
}
