//! Fig. 7 — accuracy vs %protected weights on the ImageNet-analog dataset
//! (in50s): ResNet18, ResNet34, DenseNet121; HybridAC vs IWS curves.
//!
//! One built-in study (`model` x `method` x `frac`); the series render
//! pivots it into one recovery-curve plot per model.

use hybridac::obs::Stopwatch;
use hybridac::study::{Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig7");
    let study = Study::named("fig7", "").expect("built-in study");
    let report = StudyRunner::new(hybridac::artifacts_dir()).run(&study)?;
    print!("{}", report.series("frac", "method")?);
    report.write_json()?;
    Ok(())
}
