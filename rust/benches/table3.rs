//! Table 3 — hybrid quantization (paper §5.3): digital weights at 8 bits,
//! analog at 6 bits, with the 8-bit and then the 6-bit ADC.  Compared
//! against the uniform-8-bit baseline of Table 2's first column.

use hybridac::benchkit::{built_combos, eval_budget, full_mode, Stopwatch};
use hybridac::eval::{Evaluator, Method};
use hybridac::quantize::QuantConfig;
use hybridac::report;
use hybridac::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table3");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let frac = 0.16;
    let datasets: &[&str] = if full_mode() {
        &["c10s", "c100s", "in50s"]
    } else {
        &["c10s", "in50s"]
    };

    for dataset in datasets {
        let mut rows = Vec::new();
        for (tag, pretty) in built_combos(dataset) {
            let mut ev = Evaluator::new(&dir, &tag)?;
            let mk = |q: QuantConfig, adc: u32| {
                Scenario::paper_default("table3", &tag, Method::Hybrid { frac })
                    .with_quant(Some(q))
                    .with_adc(Some(adc))
                    .with_eval(n_eval, repeats)
            };
            let u8_8 = ev.run_scenario(&mk(QuantConfig::uniform8(), 8))?;
            let h86_8 = ev.run_scenario(&mk(QuantConfig::hybrid(), 8))?;
            let h86_6 = ev.run_scenario(&mk(QuantConfig::hybrid(), 6))?;
            rows.push(vec![
                pretty.to_string(),
                report::pct(u8_8.mean),
                report::pct(h86_8.mean),
                report::pct(h86_6.mean),
            ]);
        }
        print!(
            "{}",
            report::table(
                &format!("Table 3 [{dataset}]: hybrid quantization (8-bit digital / 6-bit analog)"),
                &["DNN", "uniform-8 8b-ADC", "(8-6) 8b-ADC", "(8-6) 6b-ADC"],
                &rows
            )
        );
    }
    Ok(())
}
