//! Table 3 — hybrid quantization (paper §5.3): digital weights at 8 bits,
//! analog at 6 bits, with the 8-bit and then the 6-bit ADC. Compared
//! against the uniform-8-bit baseline of Table 2's first column.
//!
//! The three quant/ADC designs are one `variant` axis crossed with the
//! dataset's `model` axis — see `Study::named("table3-<dataset>")`.

use hybridac::obs::Stopwatch;
use hybridac::study::{full_mode, Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table3");
    let runner = StudyRunner::new(hybridac::artifacts_dir());
    let datasets: &[&str] = if full_mode() {
        &["c10s", "c100s", "in50s"]
    } else {
        &["c10s", "in50s"]
    };
    for dataset in datasets {
        let study = Study::named(&format!("table3-{dataset}"), "").expect("built-in study");
        let report = runner.run(&study)?;
        print!("{}", report.table());
        report.write_json()?;
    }
    Ok(())
}
