//! Figs. 9/10 — execution time and energy per DNN (CIFAR100-analog) across
//! architectures: Ideal-ISAAC, SRE, IWS-1, IWS-2, HybridAC-10%,
//! HybridAC-16% (ISO-accuracy assumption, as in the paper).
//!
//! Pure mapping + timing simulation (no PJRT): the mapped crossbar/digital
//! workloads flow through the analog bit-serial model, the digital cycle
//! simulator and the pipeline scheduler.

use hybridac::analog::AnalogTiming;
use hybridac::obs::Stopwatch;
use hybridac::hwmodel::tile::TileModel;
use hybridac::mapping::{map_model, simulate_exec, MapScheme};
use hybridac::report;
use hybridac::runtime::Artifact;
use hybridac::study::built_model_combos;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig9_10");
    let dir = hybridac::artifacts_dir();
    let batch = 250;

    let mut time_rows = Vec::new();
    let mut energy_rows = Vec::new();
    for (tag, pretty) in built_model_combos(&dir, "c100s") {
        let art = Artifact::load(&dir, &tag)?;
        let isaac_tile = TileModel::isaac();
        let hybrid_tile = TileModel::hybridac();

        // Ideal-ISAAC: everything analog, pipelined over 168 tiles.
        let m_isaac = map_model(&art, MapScheme::AllAnalog, 0.0);
        let isaac = simulate_exec(&m_isaac, &AnalogTiming::isaac(), &isaac_tile,
                                  168, batch, 0, 0.0, false);
        // SRE: 16 active rows + sparsity skip.
        let sre = simulate_exec(&m_isaac, &AnalogTiming::sre(), &isaac_tile,
                                168, batch, 0, 0.0, false);
        // IWS-1: single tile, reprogram every layer, SIGMA digital (25.5 W).
        let m_iws = map_model(&art, MapScheme::IwsHoles, 0.16);
        let iws1 = simulate_exec(&m_iws, &AnalogTiming::isaac(), &isaac_tile,
                                 1, batch, 128, 25.52, true);
        // IWS-2: all layers resident + hole overhead.
        let iws2 = simulate_exec(&m_iws, &AnalogTiming::isaac(), &isaac_tile,
                                 142, batch, 128, 25.52, false);
        // HybridAC-10%: undersized digital accelerator (10/16 of the units).
        let m_h10 = map_model(&art, MapScheme::Hybrid, 0.10);
        let h10 = simulate_exec(&m_h10, &AnalogTiming::hybridac(), &hybrid_tile,
                                148, batch, 95, 1.788 * 0.625, false);
        // HybridAC-16%: balanced (§5.4.2).
        let m_h16 = map_model(&art, MapScheme::Hybrid, 0.16);
        let h16 = simulate_exec(&m_h16, &AnalogTiming::hybridac(), &hybrid_tile,
                                148, batch, 152, 1.788, false);

        let all = [("ISAAC", isaac), ("SRE", sre), ("IWS-1", iws1),
                   ("IWS-2", iws2), ("HybAC-10%", h10), ("HybAC-16%", h16)];
        let mut trow = vec![pretty.to_string()];
        let mut erow = vec![pretty.to_string()];
        for (_, e) in &all {
            trow.push(report::si_time(e.seconds));
            erow.push(report::si_energy(e.energy_j));
        }
        // normalized columns vs ISAAC
        trow.push(format!("{:.2}x", all[0].1.seconds / all[5].1.seconds));
        erow.push(format!("{:.2}x", all[0].1.energy_j / all[5].1.energy_j));
        time_rows.push(trow);
        energy_rows.push(erow);
    }

    let headers = ["DNN", "ISAAC", "SRE", "IWS-1", "IWS-2",
                   "HybAC-10%", "HybAC-16%", "ISAAC/H16"];
    print!(
        "{}",
        report::table(
            "Fig. 9: execution time per batch of 250 (c100s models)",
            &headers,
            &time_rows
        )
    );
    print!(
        "{}",
        report::table(
            "Fig. 10: energy per batch of 250 (c100s models)",
            &headers,
            &energy_rows
        )
    );
    println!("paper: HybridAC-16% improves ISAAC exec time by 26% (SRE by 14%), \
              energy by 52% (40%); IWS-1 3.6x and IWS-2 1.6x slower than ISAAC.");
    Ok(())
}
