//! Fig. 8 — accuracy vs area-efficiency for ResNet18/CIFAR10-analog:
//! how each HybridAC optimization (smaller ADC, hybrid quantization,
//! differential cells) moves the design toward the ideal corner.
//!
//! The six design points are the built-in `fig8` study's `variant` axis;
//! this driver only joins each variant with its architecture's normalized
//! area-efficiency from the hardware model.

use hybridac::obs::Stopwatch;
use hybridac::hwmodel::{all_architectures, ArchSpec};
use hybridac::report;
use hybridac::study::{Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig8");
    let study = Study::named("fig8", "resnet18m_c10s").expect("built-in study");
    let rep = StudyRunner::new(hybridac::artifacts_dir()).run(&study)?;

    let archs = all_architectures();
    let isaac = archs[0].clone();
    let eff = |name: &str| -> f64 {
        archs
            .iter()
            .find(|a| a.name == name)
            .map(|a: &ArchSpec| a.norm_area_eff(&isaac))
            .unwrap_or(0.0)
    };
    // variant name -> (pretty label, matching architecture efficiency)
    let designs: &[(&str, &str, f64)] = &[
        ("ISAAC-noprot", "ISAAC (no protection)", eff("Ideal-ISAAC")),
        ("IWS-2", "IWS-2", eff("IWS-2")),
        ("HybAC-8b", "HybridAC 8b-ADC", eff("Ideal-ISAAC") * 1.05),
        ("HybAC-6b", "HybridAC 6b-ADC", eff("HybridAC") * 0.95),
        ("HybAC-6b-hq", "HybridAC 6b + hybrid quant", eff("HybridAC")),
        ("HybACDi-4b", "HybridACDi 4b-ADC", eff("HybridACDi")),
    ];
    let variant_of = |p: &hybridac::study::PointResult| -> String {
        p.axes
            .iter()
            .find(|(k, _)| k == "variant")
            .map(|(_, v)| v.clone())
            .unwrap_or_default()
    };
    let rows: Vec<Vec<String>> = rep
        .points
        .iter()
        .map(|p| {
            let variant = variant_of(p);
            let (label, e) = designs
                .iter()
                .find(|(name, _, _)| *name == variant)
                .map(|(_, label, e)| (*label, *e))
                .unwrap_or((variant.as_str(), 0.0));
            vec![label.to_string(), report::pct(p.mean), format!("{e:.2}")]
        })
        .collect();
    let clean = rep.clean.values().next().copied().unwrap_or(0.0);
    print!(
        "{}",
        report::table(
            &format!(
                "Fig. 8: accuracy vs area-efficiency, ResNet18/c10s (clean {:.1}%, ideal corner = top-right)",
                100.0 * clean
            ),
            &["design point", "accuracy", "norm. area-eff"],
            &rows
        )
    );
    rep.write_json()?;
    Ok(())
}
