//! Fig. 8 — accuracy vs area-efficiency for ResNet18/CIFAR10-analog:
//! how each HybridAC optimization (smaller ADC, hybrid quantization,
//! differential cells) moves the design toward the ideal corner.

use hybridac::benchkit::{eval_budget, Stopwatch};
use hybridac::eval::{Evaluator, Method};
use hybridac::hwmodel::{all_architectures, ArchSpec};
use hybridac::noise::CellModel;
use hybridac::quantize::QuantConfig;
use hybridac::report;
use hybridac::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig8");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let tag = "resnet18m_c10s";
    let mut ev = Evaluator::new(&dir, tag)?;
    let archs = all_architectures();
    let isaac = archs[0].clone();
    let eff = |name: &str| -> f64 {
        archs
            .iter()
            .find(|a| a.name == name)
            .map(|a: &ArchSpec| a.norm_area_eff(&isaac))
            .unwrap_or(0.0)
    };

    let frac = 0.16;
    let mk = |method: Method| {
        Scenario::paper_default("fig8", tag, method).with_eval(n_eval, repeats)
    };

    let mut rows = Vec::new();
    // (point label, accuracy scenario, matching architecture efficiency)
    let isaac_acc = ev.run_scenario(&mk(Method::NoProtection))?;
    rows.push(("ISAAC (no protection)".to_string(), isaac_acc.mean, eff("Ideal-ISAAC")));

    let iws = ev.run_scenario(&mk(Method::Iws { frac }))?;
    rows.push(("IWS-2".to_string(), iws.mean, eff("IWS-2")));

    let hy8 = ev.run_scenario(&mk(Method::Hybrid { frac }).with_adc(Some(8)))?;
    rows.push(("HybridAC 8b-ADC".to_string(), hy8.mean, eff("Ideal-ISAAC") * 1.05));

    let hy6 = ev.run_scenario(&mk(Method::Hybrid { frac }).with_adc(Some(6)))?;
    rows.push(("HybridAC 6b-ADC".to_string(), hy6.mean, eff("HybridAC") * 0.95));

    let hyq = ev.run_scenario(
        &mk(Method::Hybrid { frac })
            .with_quant(Some(QuantConfig::hybrid()))
            .with_adc(Some(6)),
    )?;
    rows.push(("HybridAC 6b + hybrid quant".to_string(), hyq.mean, eff("HybridAC")));

    let hydi = ev.run_scenario(
        &mk(Method::Hybrid { frac })
            .with_cell(CellModel::differential(0.5))
            .with_adc(Some(4)),
    )?;
    rows.push(("HybridACDi 4b-ADC".to_string(), hydi.mean, eff("HybridACDi")));

    let clean = ev.clean_accuracy(n_eval)?;
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(n, acc, e)| vec![n.clone(), report::pct(*acc), format!("{e:.2}")])
        .collect();
    print!(
        "{}",
        report::table(
            &format!("Fig. 8: accuracy vs area-efficiency, ResNet18/c10s (clean {:.1}%, ideal corner = top-right)",
                     100.0 * clean),
            &["design point", "accuracy", "norm. area-eff"],
            &table
        )
    );
    Ok(())
}
