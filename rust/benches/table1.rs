//! Table 1 — accuracy vs protected-weight percentage, IWS vs HybridAC,
//! CIFAR10/CIFAR100-analog datasets, sigma = 50%/10% (paper §5.1).
//!
//! One built-in study per dataset: a `model` axis over the paper's combos
//! crossed with a `search` axis — `none` (the "with PV" unprotected
//! column) plus the Algorithm-1 crossing for each method. The measured
//! clean accuracy per model rides along in the report.

use hybridac::obs::Stopwatch;
use hybridac::study::{Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table1");
    let runner = StudyRunner::new(hybridac::artifacts_dir());
    for dataset in ["c10s", "c100s"] {
        let study = Study::named(&format!("table1-{dataset}"), "").expect("built-in study");
        let report = runner.run(&study)?;
        print!("{}", report.table());
        report.write_json()?;
    }
    Ok(())
}
