//! Table 1 — accuracy vs protected-weight percentage, IWS vs HybridAC,
//! CIFAR10/CIFAR100-analog datasets, sigma = 50%/10% (paper §5.1).
//!
//! For each (DNN, dataset): clean accuracy, unprotected accuracy under
//! variation, then the Algorithm-1 crossing — the %weights each method
//! must protect to come within 1% (absolute) of the clean accuracy — and
//! the accuracy both methods reach at that point.

use hybridac::benchkit::{built_combos, eval_budget, Stopwatch};
use hybridac::eval::{Evaluator, ExperimentConfig, Method};
use hybridac::report;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table1");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let target_drop = 0.02; // scaled models carry a ~2% sigma_d floor (EXPERIMENTS.md)

    for dataset in ["c10s", "c100s"] {
        let mut rows = Vec::new();
        for (tag, pretty) in built_combos(dataset) {
            let mut ev = Evaluator::new(&dir, &tag)?;
            let mut base = ExperimentConfig::paper_default(Method::NoProtection);
            base.n_eval = n_eval;
            base.repeats = repeats;

            let clean = ev.clean_accuracy(n_eval)?;
            let unprot = ev.accuracy(&base)?;
            let target = clean - target_drop;

            let step = if hybridac::benchkit::full_mode() { 0.01 } else { 0.02 };
            let (f_iws, a_iws) = ev.find_protection_step(
                &base, |f| Method::Iws { frac: f }, target, 0.30, step)?;
            let (f_hyb, a_hyb) = ev.find_protection_step(
                &base, |f| Method::Hybrid { frac: f }, target, 0.30, step)?;

            rows.push(vec![
                pretty.to_string(),
                report::pct(clean),
                report::pct(unprot.mean),
                format!("{:.0}%", 100.0 * f_iws),
                report::pct(a_iws.mean),
                format!("{:.0}%", 100.0 * f_hyb),
                report::pct(a_hyb.mean),
            ]);
        }
        print!(
            "{}",
            report::table(
                &format!("Table 1 [{dataset}]: accuracy vs %selected weights (sigma 50%/10%)"),
                &["DNN", "clean", "with PV", "%sel IWS", "acc IWS",
                  "%sel HybridAC", "acc HybridAC"],
                &rows
            )
        );
    }
    Ok(())
}
