//! Tables 6/7 — chip-level power/area of HybridAC vs IWS-1/2, SIGMA,
//! FORMS, SRE and Ideal-ISAAC, recomposed from the component database.

use hybridac::obs::Stopwatch;
use hybridac::hwmodel::arch;
use hybridac::hwmodel::components::{sigma_chip, total};
use hybridac::report;

/// Paper chip totals (power mW, area mm2) for the measured-vs-paper columns.
const PAPER: &[(&str, f64, f64)] = &[
    ("HybridAC", 37_444.94, 66.39),
    ("IWS-1", 36_258.81, 97.665),
    ("IWS-2", 61_936.96, 138.65),
    ("FORMS", 66_360.8, 89.15),
    ("SRE", 54_445.88, 84.99),
    ("Ideal-ISAAC", 65_808.08, 85.09),
];

fn main() {
    let _sw = Stopwatch::start("table6_7");
    let chips = [
        arch::hybridac_chip(),
        arch::iws1_chip(),
        arch::iws2_chip(),
        arch::forms_chip(),
        arch::sre_chip(),
        arch::isaac_chip(),
    ];
    let mut rows = Vec::new();
    for chip in &chips {
        let t = chip.totals();
        let (tile_p, tile_a) = chip.tile.tile_totals();
        let paper = PAPER.iter().find(|(n, _, _)| *n == chip.name);
        rows.push(vec![
            chip.name.clone(),
            chip.n_tiles.to_string(),
            format!("{:.1}/{:.3}", tile_p, tile_a),
            format!("{:.0}", t.analog_power_mw),
            format!("{:.1}", t.analog_area_mm2),
            format!("{:.0}", t.power_mw),
            paper.map(|(_, p, _)| format!("{p:.0}")).unwrap_or_default(),
            format!("{:.1}", t.area_mm2),
            paper.map(|(_, _, a)| format!("{a:.1}")).unwrap_or_default(),
        ]);
    }
    print!(
        "{}",
        report::table(
            "Tables 6/7: chip power/area (measured vs paper)",
            &["architecture", "tiles", "tile mW/mm2", "analog mW", "analog mm2",
              "chip mW", "(paper)", "chip mm2", "(paper)"],
            &rows
        )
    );
    let (sp, sa) = total(&sigma_chip());
    println!("SIGMA digital chip: {sp:.1} mW, {sa:.2} mm2 (paper: 25520.1 / 74.4)");

    let isaac = arch::by_name("Ideal-ISAAC").unwrap().totals;
    let hy = arch::by_name("HybridAC").unwrap().totals;
    println!(
        "HybridAC vs ISAAC: area -{:.0}% power -{:.0}% (paper: -28% / -57%)",
        100.0 * (1.0 - hy.area_mm2 / isaac.area_mm2),
        100.0 * (1.0 - hy.power_mw / isaac.power_mw)
    );
}
