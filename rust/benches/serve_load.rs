//! Closed-loop serving load bench: an offered-QPS sweep against a live
//! TCP listener (`net::NetServer`) fronting an autoscaling fleet.
//!
//! Each step spawns paced closed-loop clients (every client waits for its
//! response before sending the next request, with sleeps to hit the
//! offered rate), while a sampler thread records the live replica count.
//! Per step the bench reports achieved QPS, p50/p95/p99 latency, the shed
//! fraction, and the replicas-over-time curve; after the last step it
//! watches the drain phase until the autoscaler shrinks the fleet back to
//! its minimum. Everything lands in `BENCH_serve.json`.
//!
//! Runs on the materialized synthetic artifact with the native backend,
//! so it needs no built artifacts and works in a `--no-default-features`
//! build (CI runs `cargo bench --bench serve_load -- --quick` there).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hybridac::eval::Method;
use hybridac::exec::BackendKind;
use hybridac::net::{InferOutcome, NetClient, NetServer, ServerConfig};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::scenario::Scenario;
use hybridac::serve::{AutoscaleConfig, FleetConfig, Router};
use hybridac::util::json::Json;

const MIN_REPLICAS: usize = 1;
const MAX_REPLICAS: usize = 4;

/// One offered-QPS step's raw observations.
struct StepResult {
    offered_qps: f64,
    clients: usize,
    sent: usize,
    ok: usize,
    shed: usize,
    seconds: f64,
    latencies_ms: Vec<f64>,
    replicas_over_time: Vec<(f64, usize)>,
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx]
}

/// Sample `router.active_replicas()` on a fixed cadence until `stop`.
fn spawn_sampler(
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    t0: Instant,
) -> std::thread::JoinHandle<Vec<(f64, usize)>> {
    std::thread::spawn(move || {
        let mut samples = Vec::new();
        while !stop.load(Ordering::Relaxed) {
            samples.push((t0.elapsed().as_secs_f64() * 1e3, router.active_replicas()));
            std::thread::sleep(Duration::from_millis(50));
        }
        samples.push((t0.elapsed().as_secs_f64() * 1e3, router.active_replicas()));
        samples
    })
}

/// Run one offered-QPS step: `clients` paced closed-loop connections for
/// `dur`, each recording per-request latency and shed outcomes.
fn run_step(
    addr: std::net::SocketAddr,
    router: &Arc<Router>,
    data: &Arc<DatasetBlob>,
    offered_qps: f64,
    clients: usize,
    dur: Duration,
) -> anyhow::Result<StepResult> {
    let period = Duration::from_secs_f64(clients as f64 / offered_qps);
    let t0 = Instant::now();
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = spawn_sampler(router.clone(), stop.clone(), t0);
    let mut workers = Vec::new();
    for c in 0..clients {
        let data = data.clone();
        workers.push(std::thread::spawn(move || -> anyhow::Result<(usize, usize, usize, Vec<f64>)> {
            let mut client = NetClient::connect(addr)?;
            let per = data.image_elems();
            let (mut sent, mut ok, mut shed) = (0usize, 0usize, 0usize);
            let mut lats = Vec::new();
            for j in 0.. {
                let target = period.mul_f64(j as f64);
                let elapsed = t0.elapsed();
                if elapsed < target {
                    std::thread::sleep(target - elapsed);
                }
                if t0.elapsed() >= dur {
                    break;
                }
                let idx = (c + j * clients) % data.n;
                let image = &data.images[idx * per..(idx + 1) * per];
                let sent_at = Instant::now();
                sent += 1;
                match client.infer(image)? {
                    InferOutcome::Pred(_) => {
                        ok += 1;
                        lats.push(sent_at.elapsed().as_secs_f64() * 1e3);
                    }
                    InferOutcome::Denied { .. } => shed += 1,
                }
            }
            Ok((sent, ok, shed, lats))
        }));
    }
    let (mut sent, mut ok, mut shed) = (0, 0, 0);
    let mut latencies_ms = Vec::new();
    for w in workers {
        let (s, o, sh, lats) = w.join().expect("client thread panicked")?;
        sent += s;
        ok += o;
        shed += sh;
        latencies_ms.extend(lats);
    }
    let seconds = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    let replicas_over_time = sampler.join().expect("sampler thread panicked");
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(StepResult {
        offered_qps,
        clients,
        sent,
        ok,
        shed,
        seconds,
        latencies_ms,
        replicas_over_time,
    })
}

impl StepResult {
    fn shed_fraction(&self) -> f64 {
        self.shed as f64 / self.sent.max(1) as f64
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("offered_qps".to_string(), Json::Num(self.offered_qps));
        o.insert("clients".to_string(), Json::Num(self.clients as f64));
        o.insert("sent".to_string(), Json::Num(self.sent as f64));
        o.insert("ok".to_string(), Json::Num(self.ok as f64));
        o.insert("shed".to_string(), Json::Num(self.shed as f64));
        o.insert("shed_fraction".to_string(), Json::Num(self.shed_fraction()));
        o.insert("seconds".to_string(), Json::Num(self.seconds));
        o.insert("achieved_qps".to_string(), Json::Num(self.sent as f64 / self.seconds));
        o.insert("p50_ms".to_string(), Json::Num(percentile(&self.latencies_ms, 0.50)));
        o.insert("p95_ms".to_string(), Json::Num(percentile(&self.latencies_ms, 0.95)));
        o.insert("p99_ms".to_string(), Json::Num(percentile(&self.latencies_ms, 0.99)));
        o.insert(
            "replicas_over_time".to_string(),
            Json::Arr(self.replicas_over_time.iter().map(|&(t, n)| replica_sample(t, n)).collect()),
        );
        Json::Obj(o)
    }
}

fn replica_sample(t_ms: f64, active: usize) -> Json {
    let mut o = BTreeMap::new();
    o.insert("t_ms".to_string(), Json::Num(t_ms));
    o.insert("active".to_string(), Json::Num(active as f64));
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    let mut quick = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--quick" => quick = true,
            // cargo bench passes `--bench` to the binary even with
            // harness = false
            "--bench" => {}
            s => anyhow::bail!("unknown serve_load flag '{s}' (known: --quick)"),
        }
    }

    // self-contained: materialize the synthetic artifact next to nothing
    let dir = std::env::temp_dir().join(format!("hybridac-serve-load-{}", std::process::id()));
    Artifact::materialize_synthetic(&dir)?;
    let art = Artifact::load(&dir, "synthetic")?;
    let data = Arc::new(DatasetBlob::load(&dir, &art.dataset)?);

    // one kernel thread per replica keeps the capacity of a single replica
    // well-defined, so the sweep actually exercises the autoscaler
    let sc = Scenario::paper_default("serve-load", "synthetic", Method::Hybrid { frac: 0.16 })
        .with_backend(BackendKind::Native)
        .with_threads(1);
    let mut fleet = FleetConfig::new(MIN_REPLICAS);
    fleet.max_wait = Duration::from_millis(2);
    fleet.queue_depth = 4;
    fleet = fleet.with_bounds(MIN_REPLICAS, MAX_REPLICAS).with_autoscale(
        AutoscaleConfig {
            interval: Duration::from_millis(60),
            up_after: 2,
            down_after: 5,
            ..AutoscaleConfig::default()
        },
    );
    let router = Arc::new(Router::start_scenario(dir, sc, fleet)?);
    let server = NetServer::bind("127.0.0.1:0", router.clone(), ServerConfig::default())?;
    let addr = server.local_addr();
    println!(
        "serve_load on synthetic [native]: listener {addr}, fleet {MIN_REPLICAS}..{MAX_REPLICAS}, \
         queue depth 4, window 2 ms"
    );

    // offered-QPS sweep: low (fleet idles at min) -> beyond one replica's
    // capacity (sheds appear, autoscaler grows, shed fraction falls)
    let (steps, step_dur, clients): (&[f64], Duration, usize) = if quick {
        (&[80.0, 600.0], Duration::from_millis(1200), 4)
    } else {
        (&[50.0, 200.0, 800.0, 2000.0], Duration::from_secs(3), 8)
    };

    let mut results: Vec<StepResult> = Vec::new();
    for &qps in steps {
        let r = run_step(addr, &router, &data, qps, clients, step_dur)?;
        let max_active = r.replicas_over_time.iter().map(|&(_, n)| n).max().unwrap_or(0);
        println!(
            "  offered {qps:>6.0} qps: achieved {:>6.0} qps, p50 {:.1} ms, p95 {:.1} ms, \
             p99 {:.1} ms, shed {:.1}%, replicas {}..{max_active}",
            r.sent as f64 / r.seconds,
            percentile(&r.latencies_ms, 0.50),
            percentile(&r.latencies_ms, 0.95),
            percentile(&r.latencies_ms, 0.99),
            100.0 * r.shed_fraction(),
            r.replicas_over_time.iter().map(|&(_, n)| n).min().unwrap_or(0),
        );
        results.push(r);
    }

    // drain phase: load is gone; watch the autoscaler walk back to min
    let drain_t0 = Instant::now();
    let drain_limit = Duration::from_secs(8);
    let mut drain_samples = Vec::new();
    loop {
        let active = router.active_replicas();
        drain_samples.push((drain_t0.elapsed().as_secs_f64() * 1e3, active));
        if active <= MIN_REPLICAS || drain_t0.elapsed() > drain_limit {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let final_replicas = router.active_replicas();
    println!(
        "  drain: {} replicas after {:.1}s (min {MIN_REPLICAS})",
        final_replicas,
        drain_t0.elapsed().as_secs_f64()
    );

    let fm = router.fleet_metrics();
    println!(
        "  fleet totals: {} requests, {} shed, {} scale-ups, {} scale-downs",
        fm.total.requests, fm.shed, fm.scale_ups, fm.scale_downs
    );

    let mut drain = BTreeMap::new();
    drain.insert("seconds".to_string(), Json::Num(drain_t0.elapsed().as_secs_f64()));
    drain.insert("final_replicas".to_string(), Json::Num(final_replicas as f64));
    drain.insert(
        "replicas_over_time".to_string(),
        Json::Arr(drain_samples.iter().map(|&(t, n)| replica_sample(t, n)).collect()),
    );

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serve".to_string()));
    root.insert("backend".to_string(), Json::Str("native".to_string()));
    root.insert("model".to_string(), Json::Str("synthetic".to_string()));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("min_replicas".to_string(), Json::Num(MIN_REPLICAS as f64));
    root.insert("max_replicas".to_string(), Json::Num(MAX_REPLICAS as f64));
    root.insert("scale_ups".to_string(), Json::Num(fm.scale_ups as f64));
    root.insert("scale_downs".to_string(), Json::Num(fm.scale_downs as f64));
    root.insert("steps".to_string(), Json::Arr(results.iter().map(StepResult::to_json).collect()));
    root.insert("drain".to_string(), Json::Obj(drain));
    std::fs::write("BENCH_serve.json", Json::Obj(root).to_string())?;
    println!("wrote BENCH_serve.json ({} QPS steps)", results.len());

    server.shutdown()?;
    Arc::try_unwrap(router)
        .map_err(|_| anyhow::anyhow!("router still referenced"))?
        .shutdown()
}
