//! Fig. 3 — distribution of selected important weights per layer,
//! HybridAC (channel-wise) vs IWS (individual), ResNet18/CIFAR10-analog.
//!
//! The paper's claim: HybridAC's interior-layer selection is ~4.8x more
//! uniform (std 1.37 vs 6.69), which is what permits uniform ADC/periphery
//! shrinking.  We print both the rust-side recomputation and the stats the
//! python exporter recorded.

use hybridac::obs::Stopwatch;
use hybridac::report;
use hybridac::runtime::Artifact;
use hybridac::selection::{std_dev, IwsMasks, Partition};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig3");
    let dir = hybridac::artifacts_dir();
    let art = Artifact::load(&dir, "resnet18m_c10s")?;
    let frac = 0.16;

    let part = Partition::for_fraction(&art, frac);
    let iws = IwsMasks::for_fraction(&art, frac);
    let hyb_pct = part.per_layer_pct(&art);
    let iws_pct = iws.per_layer_pct(&art);

    let mut rows = Vec::new();
    for (li, l) in art.layers.iter().enumerate() {
        rows.push(vec![
            l.name.clone(),
            l.n_weights().to_string(),
            if l.always_digital { "pinned".into() } else { format!("{:.1}%", hyb_pct[li]) },
            if l.always_digital { "pinned".into() } else { format!("{:.1}%", iws_pct[li]) },
        ]);
    }
    print!(
        "{}",
        report::table(
            "Fig. 3: %protected weights per layer, ResNet18/c10s @16%",
            &["layer", "weights", "HybridAC", "IWS"],
            &rows
        )
    );

    let interior =
        |pct: &[f64]| -> Vec<f64> {
            pct.iter()
                .zip(&art.layers)
                .filter(|(_, l)| !l.always_digital)
                .map(|(p, _)| *p)
                .collect()
        };
    let hs = std_dev(&interior(&hyb_pct));
    let is = std_dev(&interior(&iws_pct));
    println!(
        "interior-layer std: HybridAC {:.2} vs IWS {:.2} -> {:.1}x more uniform \
         (paper: 1.37 vs 6.69 = 4.8x)",
        hs,
        is,
        is / hs.max(1e-9)
    );
    println!(
        "exporter-recorded stats: {}",
        art.fig3.to_string()
    );
    Ok(())
}
