//! Table 5 — component-level power/area of HybridAC vs Ideal-ISAAC, plus
//! the §5.2 ADC-scaling claims (7-bit: -14% tile power/-7% area; 6-bit:
//! -29%/-13%).

use hybridac::obs::Stopwatch;
use hybridac::hwmodel::adc;
use hybridac::hwmodel::components::{hybridac_digital_chip, hybridac_mcu,
                                    hybridac_tile_periphery, isaac_mcu,
                                    isaac_tile_periphery, total};
use hybridac::hwmodel::TileModel;
use hybridac::report;

fn main() {
    let _sw = Stopwatch::start("table5");

    let mut rows = Vec::new();
    for (label, parts) in [
        ("HybridAC tile periphery", hybridac_tile_periphery()),
        ("Ideal-ISAAC tile periphery", isaac_tile_periphery()),
        ("HybridAC MCU", hybridac_mcu()),
        ("Ideal-ISAAC MCU", isaac_mcu()),
        ("HybridAC digital accelerator", hybridac_digital_chip()),
    ] {
        for c in &parts {
            rows.push(vec![
                label.to_string(),
                c.name.to_string(),
                format!("{:.4}", c.power_mw()),
                format!("{:.5}", c.area_mm2()),
            ]);
        }
        let (p, a) = total(&parts);
        rows.push(vec![
            label.to_string(),
            "TOTAL".to_string(),
            format!("{p:.3}"),
            format!("{a:.4}"),
        ]);
    }
    print!(
        "{}",
        report::table(
            "Table 5: component power/area (32nm, 1GHz)",
            &["block", "component", "power mW", "area mm2"],
            &rows
        )
    );

    // §5.2 tile-level ADC savings
    let (p8, a8) = TileModel::isaac().tile_totals();
    let mut save_rows = Vec::new();
    for bits in [7u32, 6, 4] {
        let (p, a) = TileModel::isaac_with_adc(bits).tile_totals();
        save_rows.push(vec![
            format!("{bits}-bit"),
            format!("{:.1}%", 100.0 * (1.0 - p / p8)),
            format!("{:.1}%", 100.0 * (1.0 - a / a8)),
            format!("{:.2}", adc::power_frac(bits)),
            format!("{:.2}", adc::area_frac(bits)),
        ]);
    }
    print!(
        "{}",
        report::table(
            "ADC resolution scaling (paper §5.2: 7-bit saves 14%/7%, 6-bit 29%/13% of the tile)",
            &["ADC", "tile power saved", "tile area saved", "ADC power frac", "ADC area frac"],
            &save_rows
        )
    );
}
