//! Fig. 11 — accuracy vs number of simultaneously activated wordlines,
//! ResNet18/CIFAR10-analog.
//!
//! Scenarios: the VTEAM baseline (R-ratio R_b, sigma 50%), improved
//! devices (2R_b & sigma/2, 3R_b & sigma/3) — all with *no* protection —
//! and HybridAC@16%, which stays within ~1% of clean even at 128
//! wordlines.  Wordline count enters twice: the ADC full scale grows with
//! the group (coarser lsb) and the exported graph variants re-group the
//! reduction dimension (artifacts resnet18m_c10s_r{16,32,64}).

use hybridac::benchkit::{eval_budget, Stopwatch};
use hybridac::eval::{Evaluator, Method};
use hybridac::noise::{fig11_scenario, CellModel};
use hybridac::report;
use hybridac::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig11");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let tag = "resnet18m_c10s";
    let mut ev = Evaluator::new(&dir, tag)?;
    let clean = ev.clean_accuracy(n_eval)?;
    let groups = [16usize, 32, 64, 128];

    let scenarios: Vec<(&str, CellModel, Method)> = vec![
        ("Rb, s=50%", fig11_scenario(1.0, 1.0), Method::NoProtection),
        ("2Rb, s/2", fig11_scenario(2.0, 2.0), Method::NoProtection),
        ("3Rb, s/3", fig11_scenario(3.0, 3.0), Method::NoProtection),
        ("HybridAC@16%", fig11_scenario(1.0, 1.0), Method::Hybrid { frac: 0.16 }),
    ];

    let mut series = Vec::new();
    for (name, cell, method) in &scenarios {
        let mut ys = Vec::new();
        for &g in &groups {
            let sc = Scenario::paper_default(name, tag, method.clone())
                .with_cell(*cell)
                .with_adc(Some(8))
                .with_group(g)
                .with_eval(n_eval, repeats);
            ys.push(100.0 * ev.run_scenario(&sc)?.mean);
        }
        series.push((*name, ys));
    }
    let xs: Vec<f64> = groups.iter().map(|&g| g as f64).collect();
    let plot_series: Vec<(&str, Vec<f64>)> = series
        .iter()
        .map(|(n, ys)| (*n, ys.clone()))
        .collect();
    print!(
        "{}",
        report::series_plot(
            &format!("Fig. 11: accuracy vs activated wordlines (clean {:.1}%)",
                     100.0 * clean),
            "wordlines",
            &xs,
            &plot_series
        )
    );
    println!("paper: unprotected designs degrade as wordlines grow; HybridAC \
              holds the drop under ~1% at 128 wordlines.");
    Ok(())
}
