//! Fig. 11 — accuracy vs number of simultaneously activated wordlines,
//! ResNet18/CIFAR10-analog.
//!
//! Device corners: the VTEAM baseline (R-ratio R_b, sigma 50%), improved
//! devices (2R_b & sigma/2, 3R_b & sigma/3) — all with *no* protection —
//! and HybridAC@16%, which stays within ~1% of clean even at 128
//! wordlines. The corners are the built-in `fig11` study's `variant` axis
//! crossed with the `group` axis; wordline count enters twice (ADC full
//! scale + the re-grouped graph variants).

use hybridac::obs::Stopwatch;
use hybridac::study::{Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("fig11");
    let study = Study::named("fig11", "resnet18m_c10s").expect("built-in study");
    let report = StudyRunner::new(hybridac::artifacts_dir()).run(&study)?;
    print!("{}", report.series("group", "variant")?);
    report.write_json()?;
    println!(
        "paper: unprotected designs degrade as wordlines grow; HybridAC \
         holds the drop under ~1% at 128 wordlines."
    );
    Ok(())
}
