//! Table 2 — accuracy vs ADC resolution (paper §5.2).
//!
//! 8/7/6-bit ADCs on the offset-subtraction designs (HybAC vs IWS) and
//! 4-bit on the differential-cell designs (HybACDi vs IWSDi).  HybridAC's
//! uniform row removal shrinks each bit-line's full scale so the coarse
//! ADC hurts far less than it hurts IWS's scattered selection.

use hybridac::benchkit::{built_combos, eval_budget, full_mode, Stopwatch};
use hybridac::eval::{Evaluator, Method};
use hybridac::noise::CellModel;
use hybridac::report;
use hybridac::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table2");
    let dir = hybridac::artifacts_dir();
    let (n_eval, repeats) = eval_budget();
    let frac = 0.16;
    let datasets: &[&str] = if full_mode() {
        &["c10s", "c100s", "in50s"]
    } else {
        &["c10s", "in50s"]
    };

    for dataset in datasets {
        let mut rows = Vec::new();
        for (tag, pretty) in built_combos(dataset) {
            let mut ev = Evaluator::new(&dir, &tag)?;
            let mut cells = Vec::new();
            let mk = |method: Method, bits: u32, cell: CellModel| {
                Scenario::paper_default("table2", &tag, method)
                    .with_adc(Some(bits))
                    .with_cell(cell)
                    .with_eval(n_eval, repeats)
            };
            for bits in [8u32, 7, 6] {
                let hy = ev.run_scenario(&mk(Method::Hybrid { frac }, bits,
                                             CellModel::offset(0.5)))?;
                let iw = ev.run_scenario(&mk(Method::Iws { frac }, bits,
                                             CellModel::offset(0.5)))?;
                cells.push(report::pct(hy.mean));
                cells.push(report::pct(iw.mean));
            }
            // 4-bit differential (HybACDi / IWSDi)
            let hy4 = ev.run_scenario(&mk(Method::Hybrid { frac }, 4,
                                          CellModel::differential(0.5)))?;
            let iw4 = ev.run_scenario(&mk(Method::Iws { frac }, 4,
                                          CellModel::differential(0.5)))?;
            cells.push(report::pct(hy4.mean));
            cells.push(report::pct(iw4.mean));
            let mut row = vec![pretty.to_string()];
            row.extend(cells);
            rows.push(row);
        }
        print!(
            "{}",
            report::table(
                &format!("Table 2 [{dataset}]: accuracy vs ADC resolution (16% protected)"),
                &["DNN", "8b HybAC", "8b IWS", "7b HybAC", "7b IWS",
                  "6b HybAC", "6b IWS", "4b HACDi", "4b IWSDi"],
                &rows
            )
        );
    }
    Ok(())
}
