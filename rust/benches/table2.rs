//! Table 2 — accuracy vs ADC resolution (paper §5.2).
//!
//! 8/7/6-bit ADCs on the offset-subtraction designs (HybAC vs IWS) and
//! 4-bit on the differential-cell designs (HybACDi vs IWSDi). HybridAC's
//! uniform row removal shrinks each bit-line's full scale so the coarse
//! ADC hurts far less than it hurts IWS's scattered selection.
//!
//! The eight design points are one `variant` axis (the 4-bit differential
//! corner is not a cross product of single knobs) crossed with the
//! dataset's `model` axis — see `Study::named("table2-<dataset>")`.

use hybridac::obs::Stopwatch;
use hybridac::study::{full_mode, Study, StudyRunner};

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("table2");
    let runner = StudyRunner::new(hybridac::artifacts_dir());
    let datasets: &[&str] = if full_mode() {
        &["c10s", "c100s", "in50s"]
    } else {
        &["c10s", "in50s"]
    };
    for dataset in datasets {
        let study = Study::named(&format!("table2-{dataset}"), "").expect("built-in study");
        let report = runner.run(&study)?;
        print!("{}", report.table());
        report.write_json()?;
    }
    Ok(())
}
