//! Performance microbenchmarks for the L3 hot paths (EXPERIMENTS.md §Perf).
//!
//! Hot paths, in execution order per sweep point:
//!   1. noise generation (gaussian fill over every analog weight),
//!   2. weight preparation (the scenario pipeline: split + quantize +
//!      perturb + polarity), with and without the extra fault stages,
//!   3. PJRT upload + execute of one batch,
//!   4. end-to-end accuracy evaluation (one repeat),
//!   5. batch-server round trip.

use std::time::Duration;

use hybridac::benchkit::{time_n, Stopwatch};
use hybridac::coordinator::BatchServer;
use hybridac::eval::{ExperimentConfig, Method};
use hybridac::runtime::{Artifact, DatasetBlob, Engine, ModelExecutor};
use hybridac::scenario::{PerturbSpec, Scenario};
use hybridac::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("perf");
    let dir = hybridac::artifacts_dir();
    let tag = "resnet18m_c10s";
    let art = Artifact::load(&dir, tag)?;
    let data = DatasetBlob::load(&dir, &art.dataset)?;
    println!("perf targets on {tag} ({} weights, batch {})", art.total_weights, art.batch);

    // 1. raw gaussian fill at weight-blob scale
    let n_weights = art.total_weights;
    let mut buf = vec![0.0f32; n_weights];
    let mut rng = Rng::new(7);
    time_n("gaussian fill (all weights)", 20, || {
        rng.fill_normal(&mut buf);
    });

    // 2. full weight preparation through the scenario pipeline
    let sc = Scenario::paper_default("perf", tag, Method::Hybrid { frac: 0.16 });
    let pipeline = sc.pipeline();
    let mut rng2 = Rng::new(8);
    time_n("pipeline.prepare() split+quant+noise", 10, || {
        let _ = pipeline.prepare(&art, &mut rng2);
    });

    // 2b. the same pipeline with the extra fault stages plugged in — the
    // marginal cost of stuck-at + drift on the preparation hot path
    let faulty = sc
        .clone()
        .with_stage(PerturbSpec::StuckAt { rate: 0.002 })
        .with_stage(PerturbSpec::Drift { t_seconds: 3600.0, nu: 0.06, nu_sigma: 0.02 })
        .pipeline();
    let mut rng2b = Rng::new(8);
    time_n("pipeline.prepare() + stuck-at + drift", 10, || {
        let _ = faulty.prepare(&art, &mut rng2b);
    });

    // 3. upload + execute one batch — full graph (both polarity paths)
    let mut engine = Engine::cpu()?;
    let mut rng3 = Rng::new(9);
    let model = pipeline.prepare(&art, &mut rng3);
    {
        let mut exec = ModelExecutor::new(&mut engine, &art, &data, art.batch, sc.group)?;
        time_n("accuracy(): full graph (wa1+wa2 paths)", 5, || {
            let _ = exec.accuracy(&model).unwrap();
        });
    }
    // 3b. the §Perf offset-only variant (skips the all-zero wa2 matmuls)
    {
        let mut exec = ModelExecutor::new_with_variant(
            &mut engine, &art, &data, art.batch, sc.group, true)?;
        time_n("accuracy(): offset-only variant graph", 5, || {
            let _ = exec.accuracy(&model).unwrap();
        });

        // 4. one full repeat (prepare + upload + execute) on the fast path
        let mut rng4 = Rng::new(10);
        time_n("full repeat (prepare + eval, offset variant)", 5, || {
            let m = pipeline.prepare(&art, &mut rng4);
            let _ = exec.accuracy(&m).unwrap();
        });
    }
    drop(engine);

    // 5. serving round trip (batched)
    let cfg = ExperimentConfig::paper_default(Method::Hybrid { frac: 0.16 });
    let server = BatchServer::start(dir.clone(), tag.to_string(), cfg,
                                    Duration::from_millis(5))?;
    let per = data.image_elems();
    let n_req = 500;
    let t = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            let idx = i % data.n;
            server.submit(data.images[idx * per..(idx + 1) * per].to_vec())
        })
        .collect();
    for rx in rxs {
        rx.recv()?;
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "  batch server: {n_req} reqs in {dt:.2}s = {:.0} req/s (mean batch {:.0}, p99 {:.1} ms)",
        n_req as f64 / dt,
        server.metrics.mean_batch_occupancy(),
        server.metrics.latency_percentile_ms(0.99)
    );
    server.shutdown()?;
    Ok(())
}
