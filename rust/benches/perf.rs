//! Performance microbenchmarks for the L3 hot paths (EXPERIMENTS.md §Perf).
//!
//! Hot paths, in execution order per sweep point:
//!   1. noise generation (gaussian fill over every analog weight),
//!   2. weight preparation (the scenario pipeline: split + quantize +
//!      perturb + polarity), with and without the extra fault stages,
//!   2c. the packed matmul micro-kernels on the artifact's real layer
//!       shapes (`matmul_kernels`),
//!   2e. incremental prepare: cached base vs per-repeat delta vs the full
//!       prepare it replaces (the repeat-loop speedup of the base cache),
//!   3. upload + execute of one batch on the selected backend,
//!   4. end-to-end accuracy evaluation (one repeat),
//!   5. batch-server round trip.
//!
//! Besides the human-readable stage lines, the run writes
//! `BENCH_perf.json` — per-stage wall-clock + throughput, keyed by
//! execution backend — so successive runs accumulate a machine-readable
//! perf trajectory.
//!
//! Backend selection: `cargo bench --bench perf -- native` (or
//! `HYBRIDAC_BACKEND=native`); default is the build default. Native kernel
//! threads come from `HYBRIDAC_THREADS` (0/absent = auto). With no built
//! artifacts, the native backend falls back to the materialized synthetic
//! artifact so the trajectory never comes up empty.
//!
//! Regression gate: `-- --baseline path/to/BENCH_perf.json` prints the
//! per-stage speedup against a prior run and exits nonzero if any stage
//! regressed by more than 1.5x.

use std::collections::BTreeMap;
use std::time::Duration;

use hybridac::obs::{time_stats, StageTiming, Stopwatch};
use hybridac::coordinator::BatchServer;
use hybridac::eval::Method;
use hybridac::exec::native::kernels::{
    crossbar_matmul_packed, crossbar_matmul_packed_with, KernelKind, KernelPath, KernelSel,
    PackedMatrix,
};
use hybridac::exec::{BackendKind, ModelExecutor, NativeConfig};
use hybridac::runtime::{Artifact, DatasetBlob};
use hybridac::scenario::{PerturbSpec, Scenario};
use hybridac::util::json::Json;
use hybridac::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let _sw = Stopwatch::start("perf");
    // backend: first non-flag CLI arg (cargo bench may pass harness flags)
    // or the HYBRIDAC_BACKEND env var; default = build default.
    // `--baseline FILE` compares this run's stages against a prior
    // BENCH_perf.json and exits nonzero on a >1.5x regression.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut backend_arg: Option<String> = None;
    let mut baseline: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--baseline" => {
                i += 1;
                baseline = Some(
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| anyhow::anyhow!("--baseline needs a file path"))?,
                );
            }
            s if s.starts_with("--baseline=") => {
                baseline = Some(s["--baseline=".len()..].to_string());
            }
            // cargo bench passes `--bench` to the binary even with
            // harness = false; every other dash argument is a typo —
            // failing loudly beats silently skipping the regression gate
            "--bench" => {}
            s if s.starts_with('-') => {
                anyhow::bail!("unknown perf-bench flag '{s}' (known: --baseline FILE)")
            }
            s => backend_arg = Some(s.to_string()),
        }
        i += 1;
    }
    let backend_kind = match backend_arg.or_else(|| std::env::var("HYBRIDAC_BACKEND").ok()) {
        Some(s) => BackendKind::parse(&s)?,
        None => BackendKind::default(),
    };
    // native kernel workers (0 = auto); a pure throughput knob
    let threads: usize = std::env::var("HYBRIDAC_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let native_cfg = NativeConfig::with_threads(threads);

    let dir = hybridac::artifacts_dir();
    let want = "resnet18m_c10s";
    let (dir, tag) = if dir.join(format!("{want}.meta.json")).exists() {
        (dir, want.to_string())
    } else if backend_kind == BackendKind::Native {
        // no artifacts: the native backend still measures the full
        // pipeline on the materialized synthetic artifact
        let tmp = std::env::temp_dir().join(format!("hybridac-perf-{}", std::process::id()));
        Artifact::materialize_synthetic(&tmp)?;
        eprintln!("[bench] artifacts not built — using the synthetic artifact (native backend)");
        (tmp, "synthetic".to_string())
    } else {
        anyhow::bail!(
            "artifacts not built (`make artifacts`); the '{}' backend has no synthetic \
             fallback — try `cargo bench --bench perf -- native`",
            backend_kind.name()
        );
    };
    let art = Artifact::load(&dir, &tag)?;
    let data = DatasetBlob::load(&dir, &art.dataset)?;
    println!(
        "perf targets on {tag} [{}] ({} weights, batch {})",
        backend_kind.name(),
        art.total_weights,
        art.batch
    );

    let mut stages: Vec<StageTiming> = Vec::new();

    // 1. raw gaussian fill at weight-blob scale — sequential, then the
    // chunk-exact parallel fill (same stream, sharded over cores)
    let n_weights = art.total_weights;
    let mut buf = vec![0.0f32; n_weights];
    let mut rng = Rng::new(7);
    stages.push(time_stats("gaussian fill (all weights)", 20, || {
        rng.fill_normal(&mut buf);
    }));
    let fill_threads = native_cfg.resolve_threads();
    let mut rng_par = Rng::new(7);
    stages.push(time_stats("gaussian fill (parallel, exact stream)", 20, || {
        rng_par.fill_normal_par(&mut buf, fill_threads);
    }));

    // 2. full weight preparation through the scenario pipeline
    let sc = Scenario::paper_default("perf", &tag, Method::Hybrid { frac: 0.16 })
        .with_backend(backend_kind)
        .with_threads(threads);
    let pipeline = sc.pipeline();
    let mut rng2 = Rng::new(8);
    stages.push(time_stats("pipeline.prepare() split+quant+noise", 10, || {
        let _ = pipeline.prepare(&art, &mut rng2);
    }));

    // 2b. the same pipeline with the extra fault stages plugged in — the
    // marginal cost of stuck-at + drift on the preparation hot path
    let faulty = sc
        .clone()
        .with_stage(PerturbSpec::StuckAt { rate: 0.002 })
        .with_stage(PerturbSpec::Drift { t_seconds: 3600.0, nu: 0.06, nu_sigma: 0.02 })
        .pipeline();
    let mut rng2b = Rng::new(8);
    stages.push(time_stats("pipeline.prepare() + stuck-at + drift", 10, || {
        let _ = faulty.prepare(&art, &mut rng2b);
    }));

    // 2e. incremental prepare: the cached deterministic base (built once
    // per (model, split, quant, group, differential) key) vs the per-repeat
    // delta (perturb + polarity on copy-on-write tensors) vs the seed full
    // prepare it replaces. delta-vs-full is the repeat-loop speedup the
    // PreparedBaseCache buys; all three feed the --baseline gate.
    let prepared_base = pipeline.prepare_base(&art);
    let base_stage = time_stats("prepare: base (split+quant+polarity)", 10, || {
        let _ = pipeline.prepare_base(&art);
    });
    let mut rng_d = Rng::new(8);
    let delta_stage = time_stats("prepare: delta (perturb-only repeat)", 20, || {
        let _ = pipeline.prepare_delta(&prepared_base, &art, &mut rng_d);
    });
    let mut rng_f = Rng::new(8);
    let full_stage = time_stats("prepare: full (uncached repeat)", 10, || {
        let _ = pipeline.prepare(&art, &mut rng_f);
    });
    let prepare_delta_speedup = full_stage.mean_s / delta_stage.mean_s.max(1e-12);
    println!("  prepare: delta repeat is {prepare_delta_speedup:.2}x faster than full prepare");
    stages.push(base_stage);
    stages.push(delta_stage);
    stages.push(full_stage);

    // 2c. the packed micro-kernels alone, on the artifact's real layer
    // shapes: k/n from the layer table, m = batch x an 8x8 output tile for
    // convs (batch alone for dense heads)
    {
        let mut shapes: Vec<(usize, usize, usize)> = art
            .layers
            .iter()
            .map(|li| {
                let m = if li.kind == "conv" { art.batch * 64 } else { art.batch };
                (m, li.rows(), li.cout)
            })
            .collect();
        shapes.dedup();
        if shapes.len() > 4 {
            // first, two spread through the middle, last
            shapes = vec![
                shapes[0],
                shapes[shapes.len() / 3],
                shapes[2 * shapes.len() / 3],
                *shapes.last().unwrap(),
            ];
        }
        let mut rng_k = Rng::new(12);
        let mut problems: Vec<(usize, usize, Vec<f32>, PackedMatrix, Vec<f32>)> = shapes
            .iter()
            .map(|&(m, k, n)| {
                let mut x = vec![0.0f32; m * k];
                rng_k.fill_normal(&mut x);
                let mut w = vec![0.0f32; k * n];
                rng_k.fill_normal(&mut w);
                (m, k, x, PackedMatrix::pack(&w, k, n), vec![0.0f32; m * n])
            })
            .collect();
        let kthreads = native_cfg.resolve_threads();
        println!("  matmul_kernels shapes: {shapes:?} ({kthreads} threads)");
        stages.push(time_stats("matmul_kernels (packed, layer shapes)", 30, || {
            for (m, k, x, pw, out) in problems.iter_mut() {
                crossbar_matmul_packed(x, *m, *k, pw, 0.05, 8.0, 128, out, kthreads);
            }
        }));

        // 2d. per-path comparison on the same shapes: scalar vs simd vs
        // int, with grid-representable operands (2^-7 step, |q| <= 127) so
        // the int path engages. Every path is bit-equal by construction;
        // the stage rows make the speedups visible in BENCH_perf.json and
        // feed the --baseline regression gate.
        let mut rng_g = Rng::new(13);
        let mut grid_problems: Vec<(usize, usize, Vec<f32>, PackedMatrix, Vec<f32>)> = shapes
            .iter()
            .map(|&(m, k, n)| {
                let gridded = |rng: &mut Rng, len: usize| -> Vec<f32> {
                    (0..len)
                        .map(|_| ((rng.below(255) as i32) - 127) as f32 / 128.0)
                        .collect()
                };
                let x = gridded(&mut rng_g, m * k);
                let w = gridded(&mut rng_g, k * n);
                (m, k, x, PackedMatrix::pack_with(&w, k, n, true), vec![0.0f32; m * n])
            })
            .collect();
        for kind in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Int] {
            let sel = KernelSel::resolve(kind);
            let mut served: Option<KernelPath> = None;
            stages.push(time_stats(
                &format!("matmul_kernels [{}]", kind.name()),
                30,
                || {
                    for (m, k, x, pw, out) in grid_problems.iter_mut() {
                        let p = crossbar_matmul_packed_with(
                            x, *m, *k, pw, 0.05, 8.0, 128, out, kthreads, sel,
                        );
                        served = Some(p);
                    }
                },
            ));
            if let Some(p) = served {
                println!("    [{}] served by the '{}' path", kind.name(), p.name());
                if kind == KernelKind::Int && p != KernelPath::Int {
                    eprintln!("    warning: int path did not engage on grid operands");
                }
            }
        }
    }

    // 3. upload + execute one batch — full graph (both polarity paths)
    let backend = backend_kind.create_with(native_cfg)?;
    let mut rng3 = Rng::new(9);
    let model = pipeline.prepare(&art, &mut rng3);
    {
        let exec = ModelExecutor::new(backend.as_ref(), &art, &data, art.batch, sc.group)?;
        stages.push(time_stats("accuracy(): full graph (wa1+wa2 paths)", 5, || {
            let _ = exec.accuracy(&model).unwrap();
        }));
    }
    // 3b. the §Perf offset-only variant (skips the all-zero wa2 matmuls)
    {
        let exec = ModelExecutor::new_with_variant(
            backend.as_ref(),
            &art,
            &data,
            art.batch,
            sc.group,
            true,
        )?;
        stages.push(time_stats("accuracy(): offset-only variant graph", 5, || {
            let _ = exec.accuracy(&model).unwrap();
        }));

        // 4. one full repeat (prepare + upload + execute) on the fast path
        let mut rng4 = Rng::new(10);
        stages.push(time_stats("full repeat (prepare + eval, offset variant)", 5, || {
            let m = pipeline.prepare(&art, &mut rng4);
            let _ = exec.accuracy(&m).unwrap();
        }));
    }
    drop(backend);

    // 5. serving round trip (batched), on the same backend
    let server = BatchServer::start_scenario(
        dir.clone(),
        Scenario::paper_default("perf-serve", &tag, Method::Hybrid { frac: 0.16 })
            .with_backend(backend_kind)
            .with_threads(threads),
        Duration::from_millis(5),
    )?;
    let per = data.image_elems();
    let n_req = 500;
    let t = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| {
            let idx = i % data.n;
            server.submit(data.images[idx * per..(idx + 1) * per].to_vec())
        })
        .collect();
    for rx in rxs {
        rx.recv()?;
    }
    let dt = t.elapsed().as_secs_f64();
    let mean_batch = server.metrics.mean_batch_occupancy();
    let p99_ms = server.metrics.latency_percentile_ms(0.99);
    println!(
        "  batch server: {n_req} reqs in {dt:.2}s = {:.0} req/s (mean batch {mean_batch:.0}, p99 {p99_ms:.1} ms)",
        n_req as f64 / dt,
    );
    server.shutdown()?;

    // machine-readable trajectory point, keyed by backend
    let mut serve = BTreeMap::new();
    serve.insert("requests".to_string(), Json::Num(n_req as f64));
    serve.insert("seconds".to_string(), Json::Num(dt));
    serve.insert("req_per_s".to_string(), Json::Num(n_req as f64 / dt));
    serve.insert("mean_batch_occupancy".to_string(), Json::Num(mean_batch));
    serve.insert("p99_ms".to_string(), Json::Num(p99_ms));

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf".to_string()));
    root.insert("backend".to_string(), Json::Str(backend_kind.name().to_string()));
    root.insert("threads".to_string(), Json::Num(native_cfg.resolve_threads() as f64));
    root.insert("model".to_string(), Json::Str(tag.clone()));
    root.insert("total_weights".to_string(), Json::Num(art.total_weights as f64));
    root.insert("batch".to_string(), Json::Num(art.batch as f64));
    root.insert("stages".to_string(), Json::Arr(stages.iter().map(StageTiming::to_json).collect()));
    root.insert(
        "prepare_delta_speedup".to_string(),
        Json::Num(prepare_delta_speedup),
    );
    root.insert("serve".to_string(), Json::Obj(serve));
    std::fs::write("BENCH_perf.json", Json::Obj(root).to_string())?;
    println!(
        "wrote BENCH_perf.json ({} stages, backend {})",
        stages.len(),
        backend_kind.name()
    );

    // regression gate: per-stage speedup vs a prior BENCH_perf.json;
    // >1.5x slower on any stage fails the run
    if let Some(path) = baseline {
        compare_to_baseline(&path, &stages)?;
    }
    Ok(())
}

/// Print per-stage speedup vs `path` (a prior `BENCH_perf.json`) and exit
/// nonzero if any matching stage regressed by more than 1.5x in mean
/// wall-clock. Stages absent from the baseline (new stages) are reported
/// but never fail the gate.
fn compare_to_baseline(path: &str, stages: &[StageTiming]) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading baseline {path}: {e}"))?;
    let base = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing baseline {path}: {e}"))?;
    let mut base_mean: BTreeMap<String, f64> = BTreeMap::new();
    if let Some(arr) = base.get("stages").and_then(Json::as_arr) {
        for s in arr {
            if let (Some(name), Some(mean)) = (
                s.get("name").and_then(Json::as_str),
                s.get("mean_s").and_then(Json::as_f64),
            ) {
                base_mean.insert(name.to_string(), mean);
            }
        }
    }
    anyhow::ensure!(!base_mean.is_empty(), "baseline {path} has no stages");
    let mut regressions: Vec<String> = Vec::new();
    println!(
        "speedup vs baseline {path} (backend {}):",
        base.get("backend").and_then(Json::as_str).unwrap_or("?")
    );
    for s in stages {
        match base_mean.get(&s.label) {
            Some(&b) if b > 0.0 && s.mean_s > 0.0 => {
                let speedup = b / s.mean_s;
                println!("  {:<44} {speedup:>7.2}x", s.label);
                if s.mean_s > 1.5 * b {
                    regressions.push(format!(
                        "{}: {:.4}s now vs {:.4}s baseline",
                        s.label, s.mean_s, b
                    ));
                }
            }
            _ => println!("  {:<44} (no baseline entry)", s.label),
        }
    }
    if !regressions.is_empty() {
        eprintln!("PERF REGRESSION (>1.5x) in {} stage(s):", regressions.len());
        for r in &regressions {
            eprintln!("  {r}");
        }
        std::process::exit(2);
    }
    Ok(())
}
