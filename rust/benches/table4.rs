//! Table 4 — peak area-/power-efficiency of all architectures, normalized
//! to Ideal-ISAAC (paper §5.4.2).  Pure hardware-model composition.

use hybridac::obs::Stopwatch;
use hybridac::hwmodel::all_architectures;
use hybridac::report;

/// Paper's published normalized values for side-by-side comparison.
const PAPER: &[(&str, f64, f64)] = &[
    ("Ideal-ISAAC", 1.0, 1.0),
    ("PUMA", 0.70, 0.79),
    ("SRE", 0.19, 0.26),
    ("FORMS8(not pruned)", 0.54, 0.61),
    ("FORMS16(not pruned)", 0.77, 0.84),
    ("DaDianNao", 0.13, 0.45),
    ("TPU", 0.08, 0.48),
    ("WAX", 0.33, 2.3),
    ("SIMBA", 0.48, 1.2),
    ("IWS-1", 0.13, 0.15),
    ("IWS-2", 0.38, 0.41),
    ("HybridAC", 1.43, 1.81),
    ("HybridACDi", 1.75, 2.5),
];

fn main() {
    let _sw = Stopwatch::start("table4");
    let archs = all_architectures();
    let isaac = archs[0].clone();
    let mut rows = Vec::new();
    for a in &archs {
        let paper = PAPER.iter().find(|(n, _, _)| *n == a.name);
        rows.push(vec![
            a.name.clone(),
            format!("{:.2}", a.norm_area_eff(&isaac)),
            paper.map(|(_, p, _)| format!("{p:.2}")).unwrap_or_default(),
            format!("{:.2}", a.norm_power_eff(&isaac)),
            paper.map(|(_, _, p)| format!("{p:.2}")).unwrap_or_default(),
        ]);
    }
    print!(
        "{}",
        report::table(
            "Table 4: peak efficiency normalized to Ideal-ISAAC (measured vs paper)",
            &["architecture", "GOPS/mm2 (ours)", "(paper)", "GOPS/W (ours)", "(paper)"],
            &rows
        )
    );
    println!(
        "Ideal-ISAAC absolute anchors: {:.0} GOPS/mm2, {:.0} GOPS/W (paper: 1912, 2510)",
        isaac.area_eff(),
        isaac.power_eff()
    );
    let hy = archs.iter().find(|a| a.name == "HybridAC").unwrap();
    println!(
        "HybridAC analog:digital area-efficiency ratio: {:.2}x (paper: 5.87x -> ~16% digital)",
        (hy.peak_gops - hy.digital_gops) / hy.totals.analog_area_mm2
            / (hy.digital_gops / hy.totals.digital_area_mm2)
    );
}
